"""Mapper x cost-model interchangeability -- the paper's core claim:
any mapper drives any cost model through the unified abstractions."""

import pytest

from repro.core.architecture import cloud_accelerator, edge_accelerator
from repro.core.cost import MaestroLikeModel, TimeloopLikeModel
from repro.core.mappers import MAPPER_REGISTRY, get_mapper
from repro.core.mapping import Mapping
from repro.core.mapspace import MapSpace
from repro.core.optimizer import union_opt
from repro.core.problem import Problem

MAPPERS = ["exhaustive", "random", "decoupled", "genetic", "heuristic"]
COST_MODELS = ["timeloop", "maestro"]


@pytest.mark.parametrize("mapper", MAPPERS)
@pytest.mark.parametrize("cm", COST_MODELS)
def test_every_mapper_drives_every_cost_model(mapper, cm):
    """The interoperability matrix the paper says prior art cannot do
    (GAMMA tied to MAESTRO, Timeloop's mapper tied to Timeloop, ...)."""
    p = Problem.gemm(32, 16, 8, word_bytes=1)
    sol = union_opt(p, edge_accelerator(), mapper=mapper, cost_model=cm, metric="edp")
    assert sol.mapping.is_legal(p, sol.search.best_mapping and edge_accelerator())
    assert sol.cost.latency_cycles > 0
    assert sol.cost.energy_pj > 0
    assert 0 < sol.cost.utilization <= 1.0
    assert sol.search.evaluated > 0
    # a loop-nest rendering exists (paper Fig. 9 output)
    assert "compute" in sol.loop_nest()


def test_search_beats_trivial_mapping():
    p = Problem.gemm(64, 64, 64, word_bytes=1)
    arch = edge_accelerator()
    cm = TimeloopLikeModel()
    trivial = cm.evaluate(p, Mapping.trivial(p, arch), arch)
    for mapper in ("heuristic", "genetic", "random"):
        sol = union_opt(p, arch, mapper=mapper, cost_model="timeloop", metric="edp")
        assert sol.cost.edp < trivial.edp, mapper
        # utilization-driven: found mapping uses many PEs
        assert sol.cost.utilization >= 0.25


def _tiny_arch():
    from repro.core.architecture import Architecture, Cluster

    return Architecture(
        "tiny",
        [
            Cluster("DRAM", 1, "X", memory_bytes=1 << 30,
                    read_energy=64.0, write_energy=64.0),
            Cluster("PE", 4, "X", memory_bytes=4096, fill_bandwidth=32e9,
                    read_energy=0.5, write_energy=0.5,
                    macs_per_cycle=1, mac_energy=0.2),
        ],
    )


def test_exhaustive_is_lower_bound_on_small_space():
    """On a space small enough to enumerate fully, no mapper beats
    exhaustive -- the optimality sanity check for the shared map-space."""
    p = Problem.gemm(8, 8, 8, word_bytes=1)
    arch = _tiny_arch()
    best = union_opt(p, arch, mapper="exhaustive", cost_model="timeloop",
                     metric="latency", max_mappings=500_000)
    for mapper in ("random", "heuristic", "genetic", "decoupled"):
        sol = union_opt(p, arch, mapper=mapper, cost_model="timeloop", metric="latency")
        assert best.cost.latency_cycles <= sol.cost.latency_cycles * (1 + 1e-9), mapper


def test_decoupled_offchip_onchip_split():
    """Marvel-style decoupled search handles a bigger problem quickly."""
    p = Problem.gemm(256, 128, 64, word_bytes=1)
    sol = union_opt(p, cloud_accelerator(), mapper="decoupled", cost_model="timeloop")
    assert sol.cost.utilization > 0.05


def test_trajectory_monotone():
    p = Problem.gemm(32, 32, 32, word_bytes=1)
    sol = union_opt(p, edge_accelerator(), mapper="genetic", cost_model="timeloop")
    vals = [v for _, v in sol.search.trajectory]
    assert all(b <= a * (1 + 1e-9) for a, b in zip(vals, vals[1:]))


def test_mapper_registry_complete():
    for m in MAPPERS:
        assert m in MAPPER_REGISTRY or get_mapper(m) is not None


def test_heuristic_chunked_climb_matches_serial_walk():
    """The speculative chunked climb (batched admission + StackedBatch
    sharing) must reproduce the serial scalar walk's accepted-move
    sequence and final best mapping exactly, for fixed seeds, across cost
    models and chunk sizes. Engine-side work counters may differ (the
    speculated tail past an accepted move is evaluated and cached), but
    the walk itself -- every accepted score, in order -- may not."""
    from repro.core.mappers.heuristic import HeuristicMapper

    p = Problem.gemm(64, 32, 16, word_bytes=1)
    for arch in (cloud_accelerator(), edge_accelerator()):
        for cm in COST_MODELS:
            for seed in (0, 7):
                serial = union_opt(
                    p, arch, mapper=HeuristicMapper(seed=seed, chunk=1),
                    cost_model=cm,
                )
                for chunk in (4, 8, 16):
                    batched = union_opt(
                        p, arch, mapper=HeuristicMapper(seed=seed, chunk=chunk),
                        cost_model=cm,
                    )
                    assert batched.cost.edp == serial.cost.edp, (cm, seed, chunk)
                    assert (
                        batched.mapping.to_dict() == serial.mapping.to_dict()
                    ), (cm, seed, chunk)
                    # accepted-move sequence: the ordered best-metric values
                    assert [s for _, s in batched.search.trajectory] == [
                        s for _, s in serial.search.trajectory
                    ], (cm, seed, chunk)


def test_heuristic_chunked_climb_uses_batched_admission():
    """The chunked climb actually reaches evaluate_batch (batched bound +
    shared StackedBatch): the engine records batches and, with pruning
    active, a nonzero bound-pruned count on this workload."""
    from repro.core.cost.engine import EvaluationEngine
    from repro.core.mappers.heuristic import HeuristicMapper

    p = Problem.gemm(64, 32, 16, word_bytes=1)
    arch = cloud_accelerator()
    cm = TimeloopLikeModel()
    engine = EvaluationEngine(cm, p, arch, metric="edp")
    HeuristicMapper(seed=0, chunk=8).search(MapSpace(p, arch), cm, engine=engine)
    assert engine.stats.batches > 0
    assert engine.stats.pruned > 0
    assert engine.stats.considered > 0
