"""Unified co-design layer (src/repro/codesign): planner edge cases,
A/B equivalence with the pre-refactor per-kernel planners, plan-cache
behavior, fallback ledger, and the calibration subsystem.

The A/B tests pin BOTH sides to the unified 8 MiB VMEM budget
(``DEFAULT_VMEM_BUDGET``): flash_attention and ssd_scan always planned at
8 MiB, while matmul historically planned at the 16 MiB ``tpu_chip()``
default -- unifying that convention is an intended behavior change of the
refactor (PR 7), so the legacy replicas here are the old ALGORITHMS run
at the new budget.
"""

import json
import math
import random

import pytest

from repro import codesign
from repro.codesign import (
    DEFAULT_VMEM_BUDGET,
    CalibrationScale,
    CalibrationTable,
    KernelSpace,
    plan,
    planner_stats,
    repair_tile,
    reset_planner_stats,
    round_up,
)
from repro.core.architecture import tpu_chip
from repro.core.constraints import mxu_aligned
from repro.core.cost.store import ResultStore
from repro.core.cost.timeloop_like import TimeloopLikeModel
from repro.core.cost.maestro_like import MaestroLikeModel
from repro.core.cost.roofline import TPURooflineModel
from repro.core.optimizer import union_opt
from repro.core.problem import Problem

jax = pytest.importorskip("jax")

from repro.kernels.flash_attention.ops import FLASH_ATTENTION_SPACE, plan_blocks
from repro.kernels.matmul.ops import MATMUL_SPACE, plan_tiles
from repro.kernels.ssd_scan.ops import SSD_SCAN_SPACE, plan_chunk

V8 = 8 * (1 << 20)


# ------------------------------------------------------------------ #
# legacy replicas: the pre-refactor planner algorithms, budget-pinned
# ------------------------------------------------------------------ #
def _legacy_fix(b, dim, default, cap=None):
    if b >= 128 and dim % b == 0 and (cap is None or b <= cap):
        return b
    d = min(default, dim)
    while dim % d != 0:
        d //= 2
    return max(d, 1)


def _legacy_plan_tiles(M, N, K, mapper="heuristic", budget=400):
    problem = Problem.gemm(M, N, K)
    arch = tpu_chip(vmem_tile_budget=V8)  # unified budget (see module doc)
    cons = mxu_aligned(["m", "n", "k"], 128)
    try:
        sol = union_opt(
            problem, arch, mapper=mapper, cost_model="timeloop",
            metric="latency", constraints=cons, climb_steps=budget,
        )
        leaf = sol.mapping.levels[-1]
        bm, bn, bk = leaf.tt("m"), leaf.tt("n"), leaf.tt("k")
    except Exception:
        bm = bn = bk = 0
    return _legacy_fix(bm, M, 256), _legacy_fix(bn, N, 256), _legacy_fix(bk, K, 512)


def _legacy_plan_blocks(Sq, Skv, D):
    problem = Problem.from_einsum(
        "attn_scores", "qd,kd->qk", {"q": Sq, "k": Skv, "d": D}, "GEMM"
    )
    cons = mxu_aligned(["q", "k"], 128)
    try:
        sol = union_opt(
            problem, tpu_chip(vmem_tile_budget=V8),
            mapper="heuristic", cost_model="timeloop",
            metric="latency", constraints=cons, climb_steps=200,
        )
        leaf = sol.mapping.levels[-1]
        bq, bk = leaf.tt("q"), leaf.tt("k")
    except Exception:
        bq = bk = 0
    return _legacy_fix(bq, Sq, 512, cap=1024), _legacy_fix(bk, Skv, 512, cap=1024)


def _legacy_plan_chunk(hp, n, vmem_budget=V8):
    cl = 1024
    while cl > 64:
        ws = 4 * (2 * cl * cl + cl * (hp + 2 * n + 2) + n * hp)
        if ws <= vmem_budget:
            return cl
        cl //= 2
    return 64


# the shapes test_kernels.py drives through each planner (matmul shapes
# are what matmul() actually plans: dims rounded up to 128)
MATMUL_AB = [
    (128, 128, 128), (256, 128, 384), (384, 256, 128), (128, 512, 256),
    (128, 384, 128), (4096, 4096, 4096), (8192, 1024, 512),
]
FLASH_AB = [(4096, 4096, 128), (128, 128, 64), (256, 128, 128)]
SSD_AB = [(64, 128), (64, 64), (256, 64)]


@pytest.mark.parametrize("mnk", MATMUL_AB)
def test_ab_matmul_tiles_match_legacy(mnk):
    assert plan_tiles(*mnk) == _legacy_plan_tiles(*mnk)


@pytest.mark.parametrize("sqd", FLASH_AB)
def test_ab_flash_blocks_match_legacy(sqd):
    assert plan_blocks(*sqd) == _legacy_plan_blocks(*sqd)


@pytest.mark.parametrize("hpn", SSD_AB)
def test_ab_ssd_chunk_matches_legacy(hpn):
    assert plan_chunk(*hpn) == _legacy_plan_chunk(*hpn)


# ------------------------------------------------------------------ #
# repair_tile / legalize edge cases: odd, non-pow2, < 128 dims
# ------------------------------------------------------------------ #
def _assert_legal(space, shape, config):
    dims = space.decode_dims
    tiles = space.block_tiles(shape, config)
    problem = space.problem(shape)
    for d, t in tiles.items():
        full = problem.dims[d]
        assert t >= 1, f"{space.name}{shape}: tile {d}={t} < 1"
        assert full % t == 0, f"{space.name}{shape}: {d}={t} !| {full}"
    assert len(config) == len(dims)


def test_repair_tile_seeded_random_edges():
    rng = random.Random(0)
    for _ in range(500):
        dim = rng.randint(1, 9000)  # odd, prime, < 128 all included
        b = rng.choice([0, 1, 7, 127, 128, 333, dim, dim * 2, 4096])
        default = rng.choice([64, 128, 256, 512])
        cap = rng.choice([None, 1024])
        t = repair_tile(b, dim, default, cap=cap)
        assert 1 <= t <= dim and dim % t == 0
        if cap is not None and b >= 128 and dim % b == 0 and b <= cap:
            assert t == b  # good candidates pass through untouched


def test_repair_tile_hypothesis_edges():
    pytest.importorskip(
        "hypothesis", reason="hypothesis not installed (see requirements-dev.txt)"
    )
    from hypothesis import given, settings, strategies as st

    @given(
        st.integers(0, 10_000), st.integers(1, 10_000),
        st.sampled_from([64, 128, 256, 512]), st.sampled_from([None, 1024]),
    )
    @settings(max_examples=200, deadline=None)
    def prop(b, dim, default, cap):
        t = repair_tile(b, dim, default, cap=cap)
        assert 1 <= t <= dim and dim % t == 0

    prop()


@pytest.mark.parametrize(
    "shape", [(300, 200, 100), (1, 257, 33), (127, 127, 127), (64, 96, 80)]
)
def test_matmul_legalize_odd_shapes(shape):
    # legalize must repair ANY candidate into legal divisor tiles
    for cand in [(0, 0, 0), (128, 128, 128), (999, 7, 1)]:
        cfg = MATMUL_SPACE.legalize(cand, shape)
        _assert_legal(MATMUL_SPACE, shape, cfg)


@pytest.mark.parametrize("shape", [(136, 72, 64), (8, 8, 8), (1024, 333, 128)])
def test_flash_legalize_odd_shapes(shape):
    for cand in [(0, 0), (2048, 2048), (512, 512)]:
        cfg = FLASH_ATTENTION_SPACE.legalize(cand, shape)
        _assert_legal(FLASH_ATTENTION_SPACE, shape, cfg)
        assert cfg[0] <= 1024 and cfg[1] <= 1024  # R3 cap


def test_ssd_legalize_is_binding():
    # the mapper hint is intentionally ignored: policy = largest pow2
    # chunk under R3 (exactly the pre-refactor plan_chunk rule)
    for hint in [(0,), (64,), (1024,)]:
        assert SSD_SCAN_SPACE.legalize(hint, (64, 128)) == (512,)
    # tiny budget degenerates to the 64 floor
    assert SSD_SCAN_SPACE.legalize((0,), (64, 128), vmem_budget=1024) == (64,)


def test_plan_search_on_odd_shapes_yields_legal_tiles():
    # full plan() path (search included) on shapes the MXU constraints
    # can only satisfy via the full-dim escape hatch
    for shape in [(300, 200, 100), (1, 257, 33)]:
        p = plan(MATMUL_SPACE, shape, store=ResultStore())
        _assert_legal(MATMUL_SPACE, shape, p.config)


# ------------------------------------------------------------------ #
# unified VMEM budget convention
# ------------------------------------------------------------------ #
def test_vmem_budget_unified():
    assert DEFAULT_VMEM_BUDGET == V8
    for space in (MATMUL_SPACE, FLASH_ATTENTION_SPACE, SSD_SCAN_SPACE):
        assert space.vmem_budget == DEFAULT_VMEM_BUDGET
        assert space.arch().clusters[-1].memory_bytes == DEFAULT_VMEM_BUDGET
    # the ssd wrapper's kwarg default follows the constant too
    import inspect

    sig = inspect.signature(plan_chunk.__wrapped__)
    assert sig.parameters["vmem_budget"].default == DEFAULT_VMEM_BUDGET


def test_vmem_budget_parameter_reaches_legality():
    # a smaller budget must shrink the planned ssd chunk
    assert plan_chunk(64, 128, vmem_budget=1 << 20) < plan_chunk(64, 128)


# ------------------------------------------------------------------ #
# plan cache: warm queries answer from the store without a search
# ------------------------------------------------------------------ #
def test_warm_plan_query_skips_search():
    store = ResultStore()
    reset_planner_stats()
    p1 = plan(MATMUL_SPACE, (128, 128, 128), store=store)
    s = planner_stats()
    assert (s["plan_searches"], s["plan_store_hits"]) == (1, 0)
    p2 = plan(MATMUL_SPACE, (128, 128, 128), store=store)
    s = planner_stats()
    assert (s["plan_searches"], s["plan_store_hits"]) == (1, 1)
    assert p2.source == "store" and p2.config == p1.config
    assert p2.cost is not None and p2.cost.latency_cycles == p1.cost.latency_cycles


def test_plan_cache_round_trips_disk(tmp_path):
    store = ResultStore(tmp_path)
    p1 = plan(MATMUL_SPACE, (256, 128, 384), store=store)
    store.flush()
    reset_planner_stats()
    p2 = plan(MATMUL_SPACE, (256, 128, 384), store=ResultStore(tmp_path))
    s = planner_stats()
    assert s["plan_searches"] == 0 and s["plan_store_hits"] == 1
    assert p2.source == "store" and p2.config == p1.config


def test_plan_key_is_constraints_and_model_inclusive():
    cons = MATMUL_SPACE.constraints((128, 128, 128))
    m = TimeloopLikeModel()
    k1 = codesign.plan_space_key(MATMUL_SPACE, cons, "heuristic", 400, "latency", m)
    k2 = codesign.plan_space_key(MATMUL_SPACE, cons, "heuristic", 100, "latency", m)
    k3 = codesign.plan_space_key(
        MATMUL_SPACE, mxu_aligned(["m", "n", "k"], 256), "heuristic", 400,
        "latency", m,
    )
    mc = TimeloopLikeModel().set_calibration(CalibrationScale(2.0, 1, "t"))
    k4 = codesign.plan_space_key(MATMUL_SPACE, cons, "heuristic", 400, "latency", mc)
    assert len({k1, k2, k3, k4}) == 4


# ------------------------------------------------------------------ #
# fallback ledger + narrow exception discipline
# ------------------------------------------------------------------ #
class _Mac3Space(KernelSpace):
    """Non-conformable with the timeloop model (unit op mac3): every
    search raises ValueError -- the EXPECTED failure class."""

    name = "_test_mac3"
    decode_dims = ("i", "j")

    def problem(self, shape):
        return Problem.mttkrp(*shape)

    def legalize(self, config, shape, vmem_budget=None):
        I, J, _K, _L = shape
        return (repair_tile(config[0], I, 64), repair_tile(config[1], J, 64))


class _BrokenSpace(_Mac3Space):
    name = "_test_broken"

    def problem(self, shape):
        raise KeyError("not a search failure")


def test_expected_search_failure_counts_fallback():
    reset_planner_stats()
    p = plan(_Mac3Space(), (64, 64, 64, 64), store=ResultStore(), predict=False)
    s = planner_stats()
    assert s["plan_fallbacks"] == 1
    assert p.source == "fallback" and p.fallback
    _assert_legal(_Mac3Space(), (64, 64, 64, 64), p.config)


def test_unexpected_errors_propagate():
    # the historical bare `except Exception` would have swallowed this
    with pytest.raises(KeyError):
        plan(_BrokenSpace(), (64, 64, 64, 64), store=ResultStore(), predict=False)


def test_fallback_plan_is_cached_with_flag():
    store = ResultStore()
    plan(_Mac3Space(), (64, 64, 64, 64), store=store, predict=False)
    reset_planner_stats()
    p = plan(_Mac3Space(), (64, 64, 64, 64), store=store, predict=False)
    assert planner_stats()["plan_searches"] == 0
    assert p.source == "store" and p.fallback


# ------------------------------------------------------------------ #
# calibration table
# ------------------------------------------------------------------ #
def test_calibration_table_round_trip(tmp_path):
    path = tmp_path / "cal.json"
    t = CalibrationTable(path)
    t.record("matmul", (128, 128, 128), (128, 128, 128), ("timeloop_like", "mac2"),
             predicted_cycles=1e6, frequency_hz=1e9, measured_s=2e-3)
    t.record("matmul", (256, 256, 256), (128, 128, 128), ("timeloop_like", "mac2"),
             predicted_cycles=8e6, frequency_hz=1e9, measured_s=1.6e-2)
    assert t.flush() == 2
    t2 = CalibrationTable(path)
    assert len(t2.rows) == 2 and t2.corrupt_payloads == 0
    sc = t2.scale_for("matmul")
    # both rows have measured/predicted = 2.0 exactly -> geomean 2.0
    assert sc.n_records == 2 and sc.scale == pytest.approx(2.0)
    rep = t2.model_error_report("matmul")
    assert len(rep) == 2
    assert all(r["abs_error_pct"] == pytest.approx(0.0, abs=1e-9) for r in rep)


def test_calibration_table_rerecord_replaces():
    t = CalibrationTable()
    for ms in (1e-3, 4e-3):
        t.record("k", (8,), (8,), ("m",), 1e6, 1e9, ms)
    assert len(t.rows) == 1 and t.rows[0]["measured_s"] == 4e-3


def test_calibration_table_tolerates_corruption(tmp_path):
    path = tmp_path / "cal.json"
    path.write_text("{ not json !!")
    t = CalibrationTable(path)
    assert t.rows == [] and t.corrupt_payloads == 1
    path.write_text(json.dumps({"version": 999, "rows": []}))
    t = CalibrationTable(path)
    assert t.rows == [] and t.version_mismatches == 1
    # bad rows inside a good payload are dropped, good ones kept
    good = {"kernel": "k", "shape": [8], "config": [8], "model": ["m"],
            "predicted_cycles": 1e6, "frequency_hz": 1e9,
            "predicted_s": 1e-3, "measured_s": 2e-3, "interpret": True,
            "repeats": 1, "ts": 0.0}
    path.write_text(json.dumps(
        {"version": 1, "rows": [good, {"kernel": 5}, "junk"]}
    ))
    t = CalibrationTable(path)
    assert len(t.rows) == 1 and t.corrupt_payloads == 2


def test_calibration_scale_validates():
    with pytest.raises(ValueError):
        CalibrationScale(0.0)
    with pytest.raises(ValueError):
        CalibrationScale(float("nan"))
    with pytest.raises(ValueError):
        CalibrationScale(float("inf"))


def test_scale_never_mixes_interpret_and_device():
    t = CalibrationTable()
    t.record("k", (8,), (8,), ("m",), 1e6, 1e9, 2e-3, interpret=True)
    t.record("k", (8,), (8,), ("m",), 1e6, 1e9, 5e-3, interpret=False)
    assert t.scale_for("k", interpret=True).scale == pytest.approx(2.0)
    assert t.scale_for("k", interpret=False).scale == pytest.approx(5.0)
    assert t.scale_for("other") is None


# ------------------------------------------------------------------ #
# calibrated cost models
# ------------------------------------------------------------------ #
@pytest.mark.parametrize(
    "model_cls", [TimeloopLikeModel, MaestroLikeModel, TPURooflineModel]
)
def test_calibrated_store_key_differs_and_rescales(model_cls):
    problem, mapping, arch = MATMUL_SPACE.canonical_mapping(
        (256, 256, 256), (128, 128, 128)
    )
    raw = model_cls()
    cal = model_cls().set_calibration(CalibrationScale(2.5, 1, "interpret:t"))
    assert raw.store_key_parts() != cal.store_key_parts()
    c_raw = raw.evaluate(problem, mapping, arch)
    c_cal = cal.evaluate(problem, mapping, arch)
    assert c_cal.latency_cycles == pytest.approx(2.5 * c_raw.latency_cycles)
    assert c_cal.energy_pj == c_raw.energy_pj
    assert c_cal.breakdown["calibration_scale"] == 2.5
    # admission invariant survives: bound scales by the same factor
    lb_raw = raw.lower_bound(problem, mapping, arch)
    lb_cal = cal.lower_bound(problem, mapping, arch)
    assert lb_cal[0] == pytest.approx(2.5 * lb_raw[0])
    assert lb_cal[0] <= c_cal.latency_cycles * (1 + 1e-12)
    # vectorized fast paths STAY available while calibrated (the scale is
    # a final multiply inside the batch programs) and match the calibrated
    # scalar path bit for bit
    assert cal.lower_bound_batch_fn(problem, arch) is not None
    assert cal.batch_admit_core_builder(problem, arch) is not None
    assert cal.batch_cost_terms_fn(problem, arch) is not None
    from repro.core.cost.analysis import get_context
    from repro.core.mapping import mapping_signature

    sig = mapping_signature(mapping, get_context(problem, arch).dims)
    (c_batch,) = cal.evaluate_signature_batch(problem, arch, [sig])
    assert c_batch.latency_cycles == c_cal.latency_cycles
    assert c_batch.energy_pj == c_cal.energy_pj
    assert c_batch.breakdown == c_cal.breakdown
    # uncalibrating restores the raw behavior exactly
    cal.set_calibration(None)
    assert cal.store_key_parts() == raw.store_key_parts()
    assert cal.evaluate(problem, mapping, arch).latency_cycles == c_raw.latency_cycles


def test_set_calibration_rejects_bad_scales():
    class _Bad:
        scale = -1.0

        def key_parts(self):
            return ()

    with pytest.raises(ValueError):
        TimeloopLikeModel().set_calibration(_Bad())


def test_calibrated_plan_keys_apart_in_store():
    store = ResultStore()
    raw = TimeloopLikeModel()
    cal = TimeloopLikeModel().set_calibration(CalibrationScale(3.0, 1, "t"))
    p_raw = plan(MATMUL_SPACE, (128, 128, 128), store=store, model=raw)
    reset_planner_stats()
    p_cal = plan(MATMUL_SPACE, (128, 128, 128), store=store, model=cal)
    # different model key parts -> different plan space key -> fresh search
    assert planner_stats()["plan_searches"] == 1
    assert p_cal.cost.latency_cycles == pytest.approx(3.0 * p_raw.cost.latency_cycles)


# ------------------------------------------------------------------ #
# measurement loop (interpret mode, CPU -- the CI configuration)
# ------------------------------------------------------------------ #
def test_calibrate_kernel_end_to_end():
    table = codesign.calibrate_kernel(
        MATMUL_SPACE, [(128, 128, 128)], store=ResultStore(), repeats=1,
    )
    assert len(table.rows) == 1
    row = table.rows[0]
    assert row["kernel"] == "matmul" and row["measured_s"] > 0
    sc = table.scale_for("matmul")
    assert sc is not None and sc.scale > 0
    rep = table.model_error_report()
    assert len(rep) == 1 and rep[0]["abs_error_pct"] == pytest.approx(0.0, abs=1e-6)
    # closing the loop: the distilled scale calibrates a model
    m = TimeloopLikeModel().set_calibration(sc)
    assert "calibrated" in m.store_key_parts()


# ------------------------------------------------------------------ #
# canonical mapping sanity
# ------------------------------------------------------------------ #
def test_canonical_mapping_rejects_non_divisor():
    with pytest.raises(ValueError):
        MATMUL_SPACE.canonical_mapping((256, 256, 256), (100, 128, 128))


def test_round_up():
    assert round_up(1, 128) == 128
    assert round_up(128, 128) == 128
    assert round_up(129, 128) == 256


def test_registry_resolves_all_kernel_spaces():
    spaces = codesign.all_spaces()
    for name in ("matmul", "flash_attention", "ssd_scan"):
        assert name in spaces
        assert codesign.get_space(name) is spaces[name]
