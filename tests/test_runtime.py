"""Runtime: fault injection/retry/restore, straggler watchdog, gradient
compression (error feedback), elastic mesh planning."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime import (
    FaultTolerantRunner,
    RunnerConfig,
    StepTimeoutError,
    compress_int8,
    decompress_int8,
    error_feedback_update,
    plan_mesh,
)
from repro.runtime.compression import compressed_wire_bytes, raw_wire_bytes


# ------------------------------------------------------------------ #
# fault tolerance
# ------------------------------------------------------------------ #
def ok_step(state, batch):
    return state + batch, {"loss": state}


def test_transient_failure_retried():
    fails = {"n": 0}

    def hook(step):
        if step == 2 and fails["n"] < 2:
            fails["n"] += 1
            raise RuntimeError("injected device error")

    r = FaultTolerantRunner(ok_step, RunnerConfig(max_retries_per_step=2),
                            fault_hook=hook)
    s = jnp.float32(0)
    for i in range(4):
        s, _ = r.run_step(s, jnp.float32(1), i)
    assert float(s) == 4.0
    assert fails["n"] == 2
    assert any(st.retried for st in r.stats)


def test_exhausted_retries_restores_from_checkpoint():
    calls = {"n": 0, "restores": 0}

    def hook(step):
        if step == 1 and calls["restores"] == 0:
            raise RuntimeError("persistent failure")

    def restore_fn():
        calls["restores"] += 1
        return jnp.float32(100), 0

    r = FaultTolerantRunner(ok_step, RunnerConfig(max_retries_per_step=1),
                            restore_fn=restore_fn, fault_hook=hook)
    s = jnp.float32(0)
    s, _ = r.run_step(s, jnp.float32(1), 0)
    s, _ = r.run_step(s, jnp.float32(1), 1)  # fails twice -> restore -> ok
    assert calls["restores"] == 1
    assert float(s) == 101.0


def test_gives_up_after_restores_exhausted():
    def hook(step):
        raise RuntimeError("unrecoverable")

    r = FaultTolerantRunner(
        ok_step, RunnerConfig(max_retries_per_step=0, max_restores=1),
        restore_fn=lambda: (jnp.float32(0), 0), fault_hook=hook,
    )
    with pytest.raises(RuntimeError):
        r.run_step(jnp.float32(0), jnp.float32(1), 0)


def test_straggler_watchdog_timeout():
    def slow_step(state, batch):
        time.sleep(1.0)
        return state, {}

    r = FaultTolerantRunner(slow_step, RunnerConfig(
        max_retries_per_step=0, max_restores=0, step_timeout_s=0.1))
    with pytest.raises(StepTimeoutError):
        r.run_step(jnp.float32(0), jnp.float32(1), 0)


def test_straggler_detection_flags_slow_step():
    delays = [0.01] * 10 + [0.2]

    def step(state, batch):
        time.sleep(delays.pop(0))
        return state, {}

    r = FaultTolerantRunner(step, RunnerConfig(straggler_slack=3.0))
    for i in range(11):
        r.run_step(jnp.float32(0), jnp.float32(1), i)
    assert r.stats[-1].straggler
    assert not any(st.straggler for st in r.stats[:-1])


# ------------------------------------------------------------------ #
# gradient compression
# ------------------------------------------------------------------ #
def test_int8_roundtrip_bounded_error():
    x = jax.random.normal(jax.random.PRNGKey(0), (1024,)) * 3.0
    q, s = compress_int8(x)
    err = np.abs(np.asarray(decompress_int8(q, s) - x))
    assert q.dtype == jnp.int8
    assert err.max() <= float(s) / 2 + 1e-6  # half-ulp rounding


def test_error_feedback_contracts():
    """With EF, the cumulative applied update converges to the cumulative
    true gradient (residual stays bounded, does not accumulate)."""
    g = jax.random.normal(jax.random.PRNGKey(1), (256,))
    res = jnp.zeros_like(g)
    applied = jnp.zeros_like(g)
    for i in range(50):
        q, s, res, deq = error_feedback_update(g, res)
        applied += deq
    # average applied per step ~ g
    np.testing.assert_allclose(np.asarray(applied / 50), np.asarray(g),
                               rtol=0, atol=float(jnp.abs(g).max()) / 100)
    assert float(jnp.abs(res).max()) <= float(jnp.abs(g).max()) / 50


def test_wire_bytes_4x():
    tree = {"a": jnp.zeros((1024,), jnp.float32), "b": jnp.zeros((512,), jnp.float32)}
    assert raw_wire_bytes(tree) == 4 * 1536
    assert compressed_wire_bytes(tree) == 1536 + 8  # int8 + 2 scales
    assert compressed_wire_bytes(tree) * 3.5 < raw_wire_bytes(tree)


def test_compressed_allreduce_in_shard_map():
    """End-to-end: compressed psum over a 1-device axis equals plain mean."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.runtime.compression import make_compressed_allreduce

    mesh = Mesh(np.array(jax.devices()[:1]), ("pod",))
    ar = make_compressed_allreduce("pod")
    g = jax.random.normal(jax.random.PRNGKey(0), (64,))
    res = jnp.zeros_like(g)
    fn = shard_map(ar, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()))
    avg, new_res = fn(g, res)
    np.testing.assert_allclose(np.asarray(avg), np.asarray(g), atol=0.05)


# ------------------------------------------------------------------ #
# elastic mesh planning
# ------------------------------------------------------------------ #
def test_plan_mesh_shrinks_dp_keeps_tp():
    class D:  # fake device
        def __init__(self, i):
            self.id = i

        def __repr__(self):
            return f"D{self.id}"

    devs = [D(i) for i in range(512)]
    m = plan_mesh(512, model=16, prefer_pods=2, devices=devs)
    assert m.devices.shape == (2, 16, 16)
    # lose 100 chips -> DP shrinks, TP intact
    m2 = plan_mesh(412, model=16, prefer_pods=2, devices=devs[:412])
    assert m2.devices.shape[-1] == 16
    assert m2.devices.size <= 412
    # catastrophic loss (< 2 pods' worth) -> collapse to a single pod
    m3 = plan_mesh(17, model=16, prefer_pods=2, devices=devs[:17])
    assert m3.devices.shape[0] == 1
    assert m3.devices.shape[-1] == 16
