"""Expert-parallel MoE (shard_map all-to-all): numerical parity with the
GSPMD baseline on a real multi-device mesh (8 fake XLA host devices in a
subprocess, since the main test process is pinned to 1 device)."""

import subprocess
import sys
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parent.parent / "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np, dataclasses
from jax.sharding import Mesh
from repro.configs.base import get_config
from repro.models import moe as moe_mod, moe_ep
from repro.sharding.hints import hints_from_mesh

cfg = dataclasses.replace(
    get_config("qwen2-moe-a2.7b").reduced(),
    n_routed_experts=6, top_k=2, d_expert=16, d_model=32, n_shared_experts=1,
    capacity_factor=8.0,  # capacious => both paths dropless => exact parity
)
mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
hints_from_mesh(mesh, None)
p = jax.tree.map(lambda a: a.astype(jnp.float32),
                 moe_mod.init_moe(jax.random.PRNGKey(0), cfg))
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32), jnp.float32)
assert moe_ep.ep_available(cfg, x)
with mesh:
    y0, a0 = jax.jit(lambda p, x: moe_mod.moe_apply(p, cfg, x))(p, x)
    y1, a1 = jax.jit(lambda p, x: moe_ep.moe_apply_ep(p, cfg, x))(p, x)
    g0 = jax.jit(jax.grad(lambda p, x: moe_mod.moe_apply(p, cfg, x)[0].sum()))(p, x)
    g1 = jax.jit(jax.grad(lambda p, x: moe_ep.moe_apply_ep(p, cfg, x)[0].sum()))(p, x)
np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), rtol=2e-4, atol=2e-4)
np.testing.assert_allclose(float(a0), float(a1), rtol=1e-5)
f0 = {jax.tree_util.keystr(k): v for k, v in jax.tree_util.tree_flatten_with_path(g0)[0]}
f1 = {jax.tree_util.keystr(k): v for k, v in jax.tree_util.tree_flatten_with_path(g1)[0]}
for k in f0:
    np.testing.assert_allclose(np.asarray(f0[k]), np.asarray(f1[k]),
                               rtol=2e-3, atol=2e-3, err_msg=k)
# expert padding path: 6 experts on a 4-way axis -> e_pad=8
print("EP_PARITY_OK")
"""


def test_ep_parity_on_8_devices():
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin:/usr/local/bin",
             "JAX_PLATFORMS": "cpu"},
        capture_output=True, text=True, timeout=600,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    assert "EP_PARITY_OK" in res.stdout


def test_ep_available_guards():
    import jax.numpy as jnp

    from repro.configs.base import get_config
    from repro.models import moe_ep
    from repro.sharding.hints import clear_hints

    clear_hints()
    cfg = get_config("qwen2-moe-a2.7b").reduced()
    x = jnp.zeros((2, 8, cfg.d_model))
    assert not moe_ep.ep_available(cfg, x)  # no hints installed -> GSPMD path
