"""IR lowering pipeline, conformability passes, TTGT algorithm exploration."""

import math

import pytest

from repro.core.architecture import cloud_accelerator
from repro.core.cost import MaestroLikeModel, TimeloopLikeModel
from repro.core.ir.conformability import conformable_models
from repro.core.ir.dialects import LayerOp, TensorType
from repro.core.ir.lowering import (
    affine_to_problem,
    generic_to_affine,
    layer_to_generic,
    lower_layer_to_problem,
)
from repro.core.ir.ttgt import best_ttgt_plan, enumerate_ttgt_plans
from repro.core.optimizer import union_opt
from repro.core.problem import Problem


def test_linear_lowering():
    op = LayerOp(
        "ffn_up", "linear",
        {"x": TensorType((128, 64)), "w": TensorType((64, 256))},
        {"y": TensorType((128, 256))},
    )
    p = lower_layer_to_problem(op)
    assert p.operation == "GEMM"
    assert p.dims == {"b": 128, "i": 64, "o": 256}
    assert p.macs == 128 * 64 * 256


def test_conv_lowering_preserves_stride():
    op = LayerOp(
        "conv1", "conv2d", {}, {},
        params=dict(N=1, K=8, C=4, X=16, Y=16, R=3, S=3, stride=2),
    )
    p = lower_layer_to_problem(op)
    assert p.operation == "CONV2D"
    assert p.attrs["stride"] == 2
    ia = p.data_space("Inputs")
    assert any(len(e.terms) == 2 for e in ia.projection)  # x*stride + r


def test_attention_ops_lower():
    qk = LayerOp("qk", "attention_qk", {}, {},
                 params=dict(B=2, H=4, Q=128, KV=128, D=64))
    p = lower_layer_to_problem(qk)
    assert p.operation == "ATTN_QK"
    assert p.macs == 2 * 4 * 128 * 128 * 64


def test_affine_render():
    op = LayerOp(
        "mm", "linear",
        {"x": TensorType((4, 8)), "w": TensorType((8, 16))},
        {"y": TensorType((4, 16))},
    )
    nest = generic_to_affine(layer_to_generic(op))
    txt = nest.render()
    assert "affine.for" in txt and "+=" in txt


def test_gather_rejected_by_loop_level():
    emb = LayerOp(
        "embed", "embedding_gather",
        {"ids": TensorType((32,), "i32"), "table": TensorType((1000, 64))},
        {"y": TensorType((32, 64))},
    )
    p = lower_layer_to_problem(emb)
    rep = conformable_models(p, [TimeloopLikeModel(), MaestroLikeModel()])
    assert not rep.ok("timeloop_like")  # gather is not affine


def test_conformability_report_mttkrp():
    p = Problem.mttkrp(8, 8, 8, 8)
    rep = conformable_models(p, [TimeloopLikeModel(), MaestroLikeModel(),
                                 TimeloopLikeModel(unit_op="mac3")])
    assert not rep.ok("timeloop_like") or TimeloopLikeModel(unit_op="mac3").conformable(p)
    assert "REJECT" in rep.render() or "OK" in rep.render()


# ------------------------------------------------------------------ #
# TTGT (paper Table III: the GEMM dims for each TCCG problem)
# ------------------------------------------------------------------ #
@pytest.mark.parametrize(
    "mk,tds,M,N,K",
    [
        (Problem.tc_intensli2, 64, 262144, 64, 64),
        (Problem.tc_intensli2, 16, 4096, 16, 16),
        (Problem.tc_ccsd7, 64, 4096, 64, 4096),
        (Problem.tc_ccsd7, 16, 256, 16, 256),
        (Problem.tc_ccsd_t4, 32, 32768, 32768, 32),
        (Problem.tc_ccsd_t4, 16, 4096, 4096, 16),
    ],
)
def test_ttgt_gemm_dims_match_paper_table3(mk, tds, M, N, K):
    p = mk(tds)
    plan = best_ttgt_plan(p)
    assert (plan.M, plan.N, plan.K) == (M, N, K)
    # flattening preserves work: GEMM macs == TC macs
    assert plan.M * plan.N * plan.K == p.macs


def test_ttgt_plans_cover_index_partition():
    p = Problem.tc_ccsd7(16)
    plans = enumerate_ttgt_plans(p)
    assert plans
    for pl in plans:
        groups = set(pl.m_group) | set(pl.n_group) | set(pl.k_group)
        assert groups == set(p.dims)


def test_ttgt_beats_native_when_underutilized():
    """Paper Fig. 8 claim: for TDS=16 on the 32x64 cloud accelerator,
    TTGT wins because native TC under-utilizes the PEs."""
    arch = cloud_accelerator()
    p = Problem.tc_intensli2(16, word_bytes=1)
    nat = union_opt(p, arch, mapper="heuristic", cost_model="timeloop", metric="edp")
    plan = best_ttgt_plan(p)
    g = plan.gemm_problem(word_bytes=1)
    ttgt = union_opt(g, arch, mapper="heuristic", cost_model="timeloop", metric="edp")
    assert ttgt.cost.edp < nat.cost.edp
    # NOTE: the paper explains the win via PE under-utilization of native
    # mappings (Fig. 9a uses 256/2048 PEs). Union's cluster-target map-space
    # is strictly richer -- several 16-sized dims can be distributed
    # CONCURRENTLY at one level, so native also reaches full utilization
    # here; the EDP gap persists through latency (recorded in
    # EXPERIMENTS.md as a beyond-paper observation).
    assert ttgt.cost.utilization >= nat.cost.utilization
