"""End-to-end integration: train driver (loss drops, resume), serve driver,
Pallas-path model parity, fault-injected training."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels as kernels_cfg
from repro.configs.base import get_config
from repro.launch import serve as serve_mod
from repro.launch import train as train_mod
from repro.models import init_params, loss_fn


def test_train_driver_loss_drops(tmp_path):
    out = train_mod.main([
        "--arch", "qwen3-0.6b_smoke", "--steps", "30", "--batch", "8",
        "--seq", "64", "--lr", "3e-3", "--warmup", "5",
    ])
    assert out["steps"] == 30
    assert out["last_loss"] < out["first_loss"] - 0.1


def test_train_driver_resume(tmp_path):
    args = ["--arch", "qwen3-0.6b_smoke", "--steps", "10", "--batch", "4",
            "--seq", "32", "--ckpt-dir", str(tmp_path), "--ckpt-every", "5"]
    out1 = train_mod.main(args)
    # second invocation restores at step 10 and is a no-op loop
    out2 = train_mod.main(args)
    assert out2["steps"] <= 1 or out2["first_loss"] <= out1["first_loss"]
    # extend the run: restores and continues to 15
    out3 = train_mod.main(args[:3] + ["15"] + args[4:])
    assert out3["steps"] == 5


def test_train_driver_with_mesh_and_microbatches():
    out = train_mod.main([
        "--arch", "qwen3-0.6b_smoke", "--steps", "6", "--batch", "4",
        "--seq", "32", "--mesh", "1,1", "--microbatches", "2",
    ])
    assert np.isfinite(out["last_loss"])


def test_serve_driver_end_to_end():
    out = serve_mod.main([
        "--arch", "qwen3-0.6b_smoke", "--batch", "2", "--requests", "5",
        "--max-new", "8", "--max-len", "64",
    ])
    assert out["requests"] == 5
    assert out["tokens"] == 5 * 8


def test_serve_wave_determinism():
    """Same requests, different wave packing -> same greedy outputs."""
    cfg = get_config("qwen3-0.6b_smoke")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=6).tolist() for _ in range(3)]

    def run(batch_slots):
        srv = serve_mod.WaveServer(cfg, params, batch_slots=batch_slots, max_len=64)
        for i, p in enumerate(prompts):
            srv.submit(serve_mod.Request(i, p, 6))
        return {r.rid: r.out for r in srv.run()}

    a, b = run(3), run(1)
    for rid in a:
        assert a[rid] == b[rid], f"request {rid}: {a[rid]} vs {b[rid]}"


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "zamba2-2.7b", "deepseek-v2-lite-16b"])
def test_pallas_path_model_parity(arch):
    """Full-model loss with Pallas kernels (interpret) == jnp path."""
    cfg = get_config(arch + "_smoke")
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab)}
    try:
        kernels_cfg.enable_pallas(False)
        l0 = float(loss_fn(cfg, params, batch, remat=False))
        kernels_cfg.enable_pallas(True, interpret=True)
        l1 = float(loss_fn(cfg, params, batch, remat=False))
    finally:
        kernels_cfg.enable_pallas(False)
    assert abs(l0 - l1) < 5e-3 * max(1.0, abs(l0))


def test_fault_injected_training_converges(tmp_path):
    """Training with injected step failures + checkpoint restores reaches
    the same region as clean training (fault tolerance end-to-end)."""
    from repro.checkpoint import CheckpointManager
    from repro.data import SyntheticLM
    from repro.launch import steps as steps_mod
    from repro.optim.optimizers import adamw
    from repro.runtime import FaultTolerantRunner, RunnerConfig

    cfg = get_config("qwen3-0.6b_smoke")
    opt = adamw(3e-3)
    step_fn = jax.jit(steps_mod.make_train_step(cfg, opt, remat=False))
    state = steps_mod.make_init_state(cfg, opt)(jax.random.PRNGKey(0))
    src = SyntheticLM(cfg.vocab, seed=0)
    ckpt = CheckpointManager(tmp_path, every=5, async_save=False)

    booms = {"n": 0}

    def hook(step):
        if step in (7, 13) and booms["n"] < 4:
            booms["n"] += 1
            raise RuntimeError("injected")

    last = {"state": state}

    def restore_fn():
        st, step, _ = ckpt.restore_latest(jax.eval_shape(lambda: last["state"]))
        return st, step

    runner = FaultTolerantRunner(
        step_fn, RunnerConfig(max_retries_per_step=1), restore_fn=restore_fn,
        fault_hook=hook,
    )
    losses = []
    for i in range(20):
        batch = {"tokens": jnp.asarray(src.batch(i, 4, 32)["tokens"])}
        state, m = runner.run_step(state, batch, i)
        last["state"] = state
        losses.append(float(m["loss"]))
        if ckpt.should_save(i + 1):
            ckpt.save(i + 1, state)
    assert booms["n"] >= 2
    assert losses[-1] < losses[0]
