"""Device-resident search loops: the mega-batch random/exhaustive
precompute and the generation-resident GA scorer must reproduce the host
loop EXACTLY -- best mapping, best cost, trajectory, engine counters and
memo contents -- while syncing the host at most once per K units."""

import math

import numpy as np
import pytest

from repro.core.architecture import cloud_accelerator, edge_accelerator
from repro.core.cost import EvaluationEngine, TimeloopLikeModel
from repro.core.device_loop import (
    DeviceGAScorer,
    device_loop_enabled,
    device_precompute,
    sync_cadence,
)
from repro.core.genome_batch import random_genome_batch
from repro.core.mappers.exhaustive import ExhaustiveMapper
from repro.core.mappers.genetic import GeneticMapper
from repro.core.mappers.random_search import RandomMapper
from repro.core.mapspace import MapSpace
from repro.core.problem import Problem

GEMM = Problem.gemm(64, 32, 16, word_bytes=1)


# ------------------------------------------------------------------ #
# knobs + gating (no jax required)
# ------------------------------------------------------------------ #


def test_sync_cadence_env(monkeypatch):
    monkeypatch.delenv("UNION_DEVICE_K", raising=False)
    assert sync_cadence() == 8
    monkeypatch.setenv("UNION_DEVICE_K", "3")
    assert sync_cadence() == 3
    monkeypatch.setenv("UNION_DEVICE_K", "0")
    assert sync_cadence() == 1  # clamped, never a zero cadence
    monkeypatch.setenv("UNION_DEVICE_K", "garbage")
    assert sync_cadence() == 8  # malformed -> default, never a crash


def test_device_loop_gating(monkeypatch):
    arch = edge_accelerator()
    eng_np = EvaluationEngine(TimeloopLikeModel(), GEMM, arch, backend="numpy")
    eng_jx = EvaluationEngine(TimeloopLikeModel(), GEMM, arch, backend="jax")
    monkeypatch.delenv("UNION_DEVICE_LOOP", raising=False)
    assert not device_loop_enabled(eng_np)
    assert device_loop_enabled(eng_jx)
    monkeypatch.setenv("UNION_DEVICE_LOOP", "0")
    assert not device_loop_enabled(eng_jx)


def test_device_primitives_degrade_to_none_on_numpy(monkeypatch):
    """Numpy engines get None/inactive primitives -- callers keep the
    host loop with zero device state touched."""
    monkeypatch.delenv("UNION_DEVICE_LOOP", raising=False)
    arch = edge_accelerator()
    eng = EvaluationEngine(TimeloopLikeModel(), GEMM, arch, backend="numpy")
    gb = random_genome_batch(MapSpace(GEMM, arch), np.random.default_rng(0), 8)
    assert device_precompute(eng, [gb]) is None
    scorer = DeviceGAScorer(eng, lambda g, cs: None)
    assert not scorer.active
    assert scorer.score(gb) is None
    scorer.flush()  # empty flush is a no-op
    assert eng.stats.device_syncs == 0 and eng.stats.n_traces == 0


# ------------------------------------------------------------------ #
# host-loop equivalence (jax)
# ------------------------------------------------------------------ #


def _run(mapper, backend):
    arch = cloud_accelerator()
    space = MapSpace(GEMM, arch)
    cm = TimeloopLikeModel()
    engine = EvaluationEngine(cm, GEMM, arch, metric="edp", backend=backend)
    res = mapper.search(space, cm, metric="edp", engine=engine)
    return res, engine


def _assert_results_equal(a, b, same_backend=True):
    assert a.best_cost.latency_cycles == b.best_cost.latency_cycles
    assert a.best_cost.energy_pj == b.best_cost.energy_pj
    assert a.best_cost.utilization == b.best_cost.utilization
    assert a.best_cost.breakdown == b.best_cost.breakdown
    assert a.best_mapping.to_dict() == b.best_mapping.to_dict()
    assert a.trajectory == b.trajectory
    assert a.evaluated == b.evaluated
    assert a.considered == b.considered
    assert a.pruned == b.pruned
    assert a.analyzed == b.analyzed
    assert a.cache_hits == b.cache_hits
    if same_backend:
        # miss-batches served by the fused program: the device loop's
        # replay counts each batch exactly like a fresh host dispatch
        # (numpy runs legitimately report 0, so jax-vs-jax only)
        assert a.fused_dispatches == b.fused_dispatches


def _assert_memos_equal(ea, eb):
    """The engines' memo caches -- same keys, same Cost values bit for
    bit (the device loop replays every commit through the same path)."""
    ka, kb = list(ea._cache.keys()), list(eb._cache.keys())
    assert ka == kb
    for k in ka:
        ca, cb = ea._cache[k], eb._cache[k]
        assert ca.latency_cycles == cb.latency_cycles
        assert ca.energy_pj == cb.energy_pj
        assert ca.utilization == cb.utilization
        assert ca.breakdown == cb.breakdown


@pytest.mark.parametrize("patience", [0, 60], ids=["no-patience", "patience"])
def test_random_device_loop_matches_host(monkeypatch, patience):
    """Device-resident random search (one mega dispatch per K chunks) ==
    host-loop jax run == numpy run, down to the memo contents."""
    pytest.importorskip("jax")
    mk = lambda: RandomMapper(
        samples=192, seed=3, batch_size=32, probe=8, patience=patience
    )
    monkeypatch.setenv("UNION_DEVICE_LOOP", "0")
    res_host, eng_host = _run(mk(), "jax")
    assert res_host.device_syncs == 0
    monkeypatch.setenv("UNION_DEVICE_LOOP", "1")
    res_dev, eng_dev = _run(mk(), "jax")
    assert not eng_dev._ctx._jax_failed
    assert res_dev.device_syncs >= 1
    _assert_results_equal(res_dev, res_host)
    _assert_memos_equal(eng_dev, eng_host)
    res_np, eng_np = _run(mk(), "numpy")
    _assert_results_equal(res_dev, res_np, same_backend=False)
    _assert_memos_equal(eng_dev, eng_np)


def test_random_device_sync_cadence(monkeypatch):
    """Chunks per host sync == UNION_DEVICE_K: 10 chunks at K=3 is
    exactly ceil(10/3) = 4 mega dispatches."""
    pytest.importorskip("jax")
    monkeypatch.setenv("UNION_DEVICE_LOOP", "1")
    monkeypatch.setenv("UNION_DEVICE_K", "3")
    mapper = RandomMapper(samples=320, seed=7, batch_size=32, patience=0)
    res, eng = _run(mapper, "jax")
    assert not eng._ctx._jax_failed
    assert res.device_syncs == math.ceil(10 / 3)
    monkeypatch.setenv("UNION_DEVICE_LOOP", "0")
    res_host, eng_host = _run(
        RandomMapper(samples=320, seed=7, batch_size=32, patience=0), "jax"
    )
    _assert_results_equal(res, res_host)
    _assert_memos_equal(eng, eng_host)


def test_exhaustive_device_loop_matches_host(monkeypatch):
    """The exhaustive mapper's windowed stream through device_precompute
    == its host loop, including the early-stop budget accounting."""
    pytest.importorskip("jax")
    mk = lambda: ExhaustiveMapper(max_mappings=200, batch_size=32)
    monkeypatch.setenv("UNION_DEVICE_LOOP", "0")
    res_host, eng_host = _run(mk(), "jax")
    monkeypatch.setenv("UNION_DEVICE_LOOP", "1")
    res_dev, eng_dev = _run(mk(), "jax")
    assert not eng_dev._ctx._jax_failed
    _assert_results_equal(res_dev, res_host)
    _assert_memos_equal(eng_dev, eng_host)
    res_np, _ = _run(mk(), "numpy")
    _assert_results_equal(res_dev, res_np, same_backend=False)


def test_genetic_device_loop_matches_host(monkeypatch):
    """Generation-resident GA: device-scalarized fitness drives the SAME
    population dynamics, and the K-deferred replay reproduces the host
    loop's incumbent/trajectory/memo exactly."""
    pytest.importorskip("jax")
    mk = lambda: GeneticMapper(population=16, generations=8, seed=5)
    monkeypatch.setenv("UNION_DEVICE_LOOP", "0")
    res_host, eng_host = _run(mk(), "jax")
    assert res_host.device_syncs == 0
    monkeypatch.setenv("UNION_DEVICE_LOOP", "1")
    res_dev, eng_dev = _run(mk(), "jax")
    assert not eng_dev._ctx._jax_failed
    # initial pop + 8 generations = 9 scored batches, K=8 -> <= 2 syncs
    assert 1 <= res_dev.device_syncs <= math.ceil(9 / sync_cadence()) + 1
    _assert_results_equal(res_dev, res_host)
    _assert_memos_equal(eng_dev, eng_host)
    res_np, eng_np = _run(mk(), "numpy")
    _assert_results_equal(res_dev, res_np, same_backend=False)
    _assert_memos_equal(eng_dev, eng_np)


def test_genetic_device_fitness_is_engine_metric(monkeypatch):
    """The fitness vector fetched per generation is the engine metric of
    the replayed costs, bit for bit (the GA's selection sees EXACTLY the
    values the host loop would compute)."""
    pytest.importorskip("jax")
    monkeypatch.setenv("UNION_DEVICE_LOOP", "1")
    arch = cloud_accelerator()
    eng = EvaluationEngine(TimeloopLikeModel(), GEMM, arch, metric="edp", backend="jax")
    gb = random_genome_batch(MapSpace(GEMM, arch), np.random.default_rng(1), 16)
    got = {}
    scorer = DeviceGAScorer(eng, lambda g, cs: got.__setitem__("costs", cs))
    assert scorer.active
    fitness = scorer.score(gb)
    assert fitness is not None and fitness.dtype == np.float64
    scorer.flush()
    costs = got["costs"]
    assert len(costs) == len(gb) and all(c is not None for c in costs)
    host = np.asarray([c.metric("edp") for c in costs], dtype=np.float64)
    assert np.array_equal(fitness, host)
    assert eng.stats.device_syncs == 1
