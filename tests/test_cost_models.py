"""Cost-model invariants (Timeloop-like, MAESTRO-like, energy model)."""

import math

import pytest

from repro.core.architecture import (
    chiplet_accelerator,
    cloud_accelerator,
    edge_accelerator,
    tpu_chip,
    tpu_v5e_pod,
)
from repro.core.cost import MaestroLikeModel, TimeloopLikeModel
from repro.core.mapping import Mapping
from repro.core.optimizer import union_opt
from repro.core.problem import Problem


def test_compute_lower_bound():
    """Latency can never beat macs / peak_macs_per_cycle."""
    p = Problem.gemm(64, 64, 64, word_bytes=1)
    arch = edge_accelerator()
    sol = union_opt(p, arch, mapper="heuristic", cost_model="timeloop", metric="latency")
    assert sol.cost.latency_cycles >= p.macs / arch.peak_macs_per_cycle - 1e-6


def test_trivial_mapping_latency_is_serial():
    p = Problem.gemm(16, 16, 16, word_bytes=1)
    arch = edge_accelerator()
    c = TimeloopLikeModel().evaluate(p, Mapping.trivial(p, arch), arch)
    assert c.latency_cycles >= p.macs  # one PE, one MAC/cycle


def test_more_pes_helps():
    p = Problem.gemm(128, 128, 128, word_bytes=1)
    edge = union_opt(p, edge_accelerator(), mapper="heuristic", cost_model="timeloop",
                     metric="latency")
    cloud = union_opt(p, cloud_accelerator(), mapper="heuristic", cost_model="timeloop",
                      metric="latency")
    assert cloud.cost.latency_cycles < edge.cost.latency_cycles


def test_fill_bandwidth_monotonicity_fig11_property():
    """The paper's Fig. 11 shape: EDP non-increasing in chiplet fill bw,
    saturating once compute-bound."""
    p = Problem.gemm(512, 512, 512, word_bytes=1)
    edps = []
    for bw in [1e9, 2e9, 4e9, 8e9, 16e9, 32e9]:
        arch = chiplet_accelerator(fill_bandwidth=bw)
        sol = union_opt(p, arch, mapper="heuristic", cost_model="timeloop", metric="edp")
        edps.append(sol.cost.edp)
    for a, b in zip(edps, edps[1:]):
        assert b <= a * 1.05  # non-increasing (5% search noise)
    assert edps[-1] < edps[0]  # the sweep actually matters at the low end


def test_maestro_like_operation_gate():
    p = Problem.gemm(32, 32, 32, word_bytes=1)
    cm = MaestroLikeModel()
    assert cm.conformable(p)
    p_noop = Problem.from_einsum("x", "ab,bc->ac", {"a": 4, "b": 4, "c": 4})
    p_noop.operation = None
    assert not cm.conformable(p_noop)


def test_timeloop_unit_op_gate():
    mttkrp = Problem.mttkrp(8, 8, 8, 8)
    assert not TimeloopLikeModel(unit_op="mac2").conformable(mttkrp)
    assert TimeloopLikeModel(unit_op="mac3").conformable(mttkrp)
    with pytest.raises(ValueError):
        union_opt(mttkrp, edge_accelerator(), mapper="random", cost_model="timeloop")


def test_both_models_agree_on_direction():
    """Models differ in absolute numbers but must agree that a high-
    utilization mapping beats the trivial serial one."""
    p = Problem.gemm(64, 64, 64, word_bytes=1)
    arch = edge_accelerator()
    triv = Mapping.trivial(p, arch)
    for cm in (TimeloopLikeModel(), MaestroLikeModel()):
        sol = union_opt(p, arch, mapper="heuristic", cost_model=cm, metric="edp")
        assert sol.cost.edp < cm.evaluate(p, triv, arch).edp


def test_tpu_presets():
    chip = tpu_chip()
    assert chip.clusters[-1].macs_per_cycle == 128 * 128 * 4
    # peak flops calibration: 2 * macs/cycle * freq == 197 TF
    assert math.isclose(
        2 * chip.peak_macs_per_cycle * chip.frequency_hz, 197e12, rel_tol=1e-6
    )
    pod = tpu_v5e_pod(pods=2)
    assert pod.num_pes == 2 * 16 * 16
    names = [c.dimension for c in pod.clusters]
    assert "pod" in names and "data" in names and "model" in names


def test_energy_breakdown_positive():
    p = Problem.gemm(32, 32, 32, word_bytes=1)
    arch = edge_accelerator()
    sol = union_opt(p, arch, mapper="heuristic", cost_model="timeloop", metric="energy")
    assert sol.cost.breakdown["energy_mac_pj"] > 0
    assert sol.cost.energy_pj >= sol.cost.breakdown["energy_mac_pj"]
