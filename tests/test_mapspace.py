"""Map-space enumeration / sampling / mutation legality."""

import random

from repro.core.architecture import edge_accelerator
from repro.core.constraints import Constraints, nvdla_style
from repro.core.mapspace import MapSpace, divisors
from repro.core.problem import Problem


def space(m=16, n=8, k=4, cons=None):
    return MapSpace(Problem.gemm(m, n, k), edge_accelerator(), cons)


def test_divisors():
    assert divisors(12) == [1, 2, 3, 4, 6, 12]
    assert divisors(1) == [1]
    assert divisors(7) == [1, 7]


def test_enumerate_all_legal_and_unique():
    sp = space()
    seen = set()
    for m in sp.enumerate_tilings(max_mappings=200):
        assert m.is_legal(sp.problem, sp.arch)
        key = m.to_json()
        assert key not in seen
        seen.add(key)
    assert len(seen) > 10


def test_random_mappings_legal():
    sp = space(32, 32, 32)
    rng = random.Random(0)
    for _ in range(25):
        m = sp.random_mapping(rng)
        assert m.is_legal(sp.problem, sp.arch)


def test_mutate_preserves_legality():
    sp = space(32, 32, 32)
    rng = random.Random(1)
    m = sp.random_mapping(rng)
    for _ in range(20):
        m = sp.mutate(m, rng)
        assert m.is_legal(sp.problem, sp.arch)


def test_crossover_preserves_legality():
    sp = space(32, 32, 32)
    rng = random.Random(2)
    a, b = sp.random_mapping(rng), sp.random_mapping(rng)
    for _ in range(10):
        c = sp.crossover(a, b, rng)
        assert c.is_legal(sp.problem, sp.arch)


def test_constraints_prune_spatial_dims():
    # NVDLA-style: only c/k (here: only k/n) may be spatial
    cons = Constraints(name="t", allowed_spatial_dims={"*": {"n", "k"}})
    sp = space(16, 16, 16, cons)
    rng = random.Random(0)
    for _ in range(10):
        m = sp.random_mapping(rng)
        for i in range(len(m.levels)):
            fan = m.spatial_fanout(i, sp.problem)
            assert fan.get("m", 1) == 1  # m never parallelized


def test_size_log10_positive():
    assert space(64, 64, 64).size_log10() > 2
