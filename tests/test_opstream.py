"""Whole-model operator streams: shared builders, dedup/multiplicity
semantics, MODEL_FLOPS reconciliation, and the one-sweep end-to-end path.

Covers the OpStream contract (docs/whole_model.md): every contraction
routes through the IR lowering and is bit-identical to the historical
ad-hoc ``Problem.*`` constructors; (ModelConfig, ShapeConfig) cells lower
to deduplicated ``(Problem, multiplicity, role)`` streams whose
parameter-role FLOPs reconcile with the MODEL_FLOPS convention; and
several models sweep through ONE ``union_opt_sweep`` with cross-op
engine/memo sharing and aggregate to end-to-end latency/energy/EDP.
"""

import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.workloads import dnn_layers, tc_problems  # noqa: E402
from repro.configs.base import SHAPES, ShapeConfig, get_config, list_configs
from repro.core.architecture import cloud_accelerator
from repro.core.opstream import (
    PARAM_ROLES,
    RECONCILE_BAND,
    aggregate_stream_costs,
    build_conv2d,
    build_einsum,
    build_gemm,
    build_opstream,
    build_tc_ccsd7,
    build_tc_ccsd_t4,
    build_tc_intensli2,
    formula_model_flops,
    moe_expert_capacity,
    reconcile_model_flops,
    reconcile_with_artifact,
    stream_sweep_tasks,
)
from repro.core.optimizer import union_opt_sweep
from repro.core.problem import Problem

ART_DIR = Path(__file__).resolve().parent.parent / "experiments" / "dryrun"

SMALL = ShapeConfig("t_prefill", 128, 2, "prefill")
SMALL_DECODE = ShapeConfig("t_decode", 256, 64, "decode")
SMALL_TRAIN = ShapeConfig("t_train", 128, 4, "train")

TARGETS = ["qwen3-0.6b", "deepseek-v2-lite-16b", "zamba2-2.7b"]


# --------------------------------------------------------------------- #
# shared builders: bit-identical to the ad-hoc constructors
# --------------------------------------------------------------------- #
def test_builders_bit_identical_to_adhoc_constructors():
    """The IR-routed builders must produce EXACTLY the Problems the
    historical constructors did -- every field, including name, dim
    insertion order, data-space names/projections and attrs."""
    pairs = [
        (build_gemm(512, 1024, 64, name="g", word_bytes=1),
         Problem.gemm(512, 1024, 64, name="g", word_bytes=1)),
        (build_conv2d(32, 64, 64, 56, 56, 3, 3, name="c", word_bytes=1),
         Problem.conv2d(32, 64, 64, 56, 56, 3, 3, name="c", word_bytes=1)),
        (build_conv2d(1, 8, 4, 16, 16, 3, 3, stride=2, name="s"),
         Problem.conv2d(1, 8, 4, 16, 16, 3, 3, stride=2, name="s")),
        (build_tc_intensli2(16, word_bytes=1), Problem.tc_intensli2(16, word_bytes=1)),
        (build_tc_ccsd7(64, word_bytes=1), Problem.tc_ccsd7(64, word_bytes=1)),
        (build_tc_ccsd_t4(32, word_bytes=1), Problem.tc_ccsd_t4(32, word_bytes=1)),
        (build_einsum("e", "ij,jk->ik", {"i": 4, "j": 8, "k": 2}, "GEMM", 2),
         Problem.from_einsum("e", "ij,jk->ik", {"i": 4, "j": 8, "k": 2},
                             operation="GEMM", word_bytes=2)),
    ]
    for built, adhoc in pairs:
        assert built == adhoc, f"{built.name}: builder != ad-hoc constructor"
        assert built.attrs == adhoc.attrs


def test_workloads_tables_rebuilt_bit_identically():
    """A/B: benchmarks/workloads.py on the shared builders must emit the
    same Problems the Problem.* constructors produced (figure tables
    fig3/fig8/fig10/fig11 all source from these two functions)."""
    layers = dnn_layers()
    expect = {
        "ResNet50-1": Problem.conv2d(32, 64, 64, 56, 56, 1, 1, name="ResNet50-1", word_bytes=1),
        "ResNet50-2": Problem.conv2d(32, 64, 64, 56, 56, 3, 3, name="ResNet50-2", word_bytes=1),
        "ResNet50-3": Problem.conv2d(32, 512, 1024, 14, 14, 1, 1, name="ResNet50-3", word_bytes=1),
        "DLRM-1": Problem.gemm(512, 1024, 1024, name="DLRM-1", word_bytes=1),
        "DLRM-2": Problem.gemm(512, 64, 1024, name="DLRM-2", word_bytes=1),
        "DLRM-3": Problem.gemm(512, 2048, 2048, name="DLRM-3", word_bytes=1),
        "BERT-1": Problem.gemm(256, 768, 768, name="BERT-1", word_bytes=1),
        "BERT-2": Problem.gemm(256, 768, 3072, name="BERT-2", word_bytes=1),
        "BERT-3": Problem.gemm(256, 3072, 768, name="BERT-3", word_bytes=1),
    }
    assert set(layers) == set(expect)
    for name, p in expect.items():
        assert layers[name] == p, f"{name} drifted off the ad-hoc constructor"
    tc_expect = {
        ("intensli2", 16): Problem.tc_intensli2(16, word_bytes=1),
        ("ccsd7", 16): Problem.tc_ccsd7(16, word_bytes=1),
        ("intensli2", 64): Problem.tc_intensli2(64, word_bytes=1),
        ("ccsd7", 64): Problem.tc_ccsd7(64, word_bytes=1),
        ("ccsd-t4", 16): Problem.tc_ccsd_t4(16, word_bytes=1),
        ("ccsd-t4", 32): Problem.tc_ccsd_t4(32, word_bytes=1),
    }
    got = {(n, tds): p for n, tds, p in tc_problems()}
    assert got == tc_expect


# --------------------------------------------------------------------- #
# stream lowering: every config, dedup/multiplicity, roles
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("name", list_configs())
def test_every_config_lowers_and_reconciles(name):
    cfg = get_config(name)
    stream = build_opstream(cfg, SMALL)
    assert len(stream) > 0
    # dedup invariant: multiplicities sum back to the pre-dedup op count
    assert sum(e.multiplicity for e in stream.entries) == stream.meta["n_ops_pre_dedup"]
    assert len(stream) < stream.meta["n_ops_pre_dedup"], "dedup found nothing"
    # parameter-role FLOPs reconcile with the MODEL_FLOPS convention
    r = reconcile_model_flops(stream, cfg)
    lo, hi = RECONCILE_BAND
    assert lo <= r["ratio"] <= hi, f"{name}: ratio {r['ratio']:.3f} off band"
    # every entry's problem lowered through the IR with a role attached
    for e in stream.entries:
        assert e.role in PARAM_ROLES + ("attention_score", "ssm_scan")
        assert e.problem.macs > 0


def test_family_coverage_roles():
    """Dense, MoE and hybrid streams expose their family-specific roles."""
    roles = {m: set(build_opstream(m, SMALL).flops_by_role())
             for m in TARGETS}
    assert {"attention", "attention_score", "mlp", "embed", "head"} <= roles["qwen3-0.6b"]
    assert {"moe", "router"} <= roles["deepseek-v2-lite-16b"]
    assert {"ssm", "ssm_scan", "attention"} <= roles["zamba2-2.7b"]
    assert "moe" not in roles["qwen3-0.6b"]


def test_gqa_decode_shapes_at_serving_batch():
    """Decode streams carry Q=1 attention at the serving batch size, with
    the config's GQA KV sharing in the projection shapes."""
    cfg = get_config("qwen3-0.6b")
    stream = build_opstream(cfg, SMALL_DECODE, serving_batch=32)
    qk = [e for e in stream.entries if e.problem.operation == "ATTN_QK"]
    assert len(qk) == 1
    dims = qk[0].problem.dims
    assert dims["b"] == 32 and dims["q"] == 1 and dims["k"] == SMALL_DECODE.seq_len
    assert qk[0].multiplicity == cfg.n_layers
    # GQA: wk/wv project to n_kv_heads*head_dim < n_heads*head_dim, so the
    # kv projection GEMM is a distinct (deduplicated x2: wk+wv) entry
    kv_dim = cfg.n_kv_heads * cfg.head_dim
    kv = [e for e in stream.entries
          if e.role == "attention" and e.problem.dims.get("o") == kv_dim]
    assert kv and kv[0].multiplicity == 2 * cfg.n_layers


def test_moe_expert_multiplicity_follows_capacity_rule():
    """MoE expert GEMMs carry the models/moe.py capacity dispatch: E
    experts x C = ceil(T*k*cf/e) token slots, gate+up merged at x2."""
    cfg = get_config("deepseek-v2-lite-16b")
    stream = build_opstream(cfg, SMALL)
    T = stream.meta["tokens_per_step"]
    C = moe_expert_capacity(cfg, T)
    up = [e for e in stream.entries
          if e.role == "moe" and e.problem.dims.get("o") == cfg.d_expert
          and e.problem.dims.get("e") == cfg.n_routed_experts]
    assert up, "no routed-expert GEMM in the MoE stream"
    assert up[0].problem.dims["t"] == C
    n_moe_layers = cfg.n_layers - cfg.first_k_dense
    assert up[0].multiplicity == 2 * n_moe_layers  # gate+up per MoE layer
    routers = [e for e in stream.entries if e.role == "router"]
    assert routers and routers[0].multiplicity == n_moe_layers


def test_ssd_scan_ops_present_and_chunked():
    """Hybrid prefill streams contain the 4 chunked-SSD contractions with
    the models/ssm.py chunking (C = batch x ceil(S/chunk))."""
    cfg = get_config("zamba2-2.7b")
    stream = build_opstream(cfg, SMALL)
    ssd = [e for e in stream.entries if e.problem.operation == "SSD"]
    assert len(ssd) == 4
    chunk = min(256, SMALL.seq_len)
    nc = SMALL.global_batch * max(1, SMALL.seq_len // chunk)
    for e in ssd:
        assert e.problem.dims["c"] == nc
    # decode swaps the chunked scan for the O(1) recurrent update
    dstream = build_opstream(cfg, SMALL_DECODE)
    dssd = [e for e in dstream.entries if e.problem.operation == "SSD"]
    assert len(dssd) == 2
    for e in dssd:
        assert "l" not in e.problem.dims  # no sequence axis in the step


def test_embed_entry_unmappable_gather():
    """The embedding gather lowers to the onehot matmul the conformability
    pass rejects -- it must be excluded from the sweep and carry the
    gather attr for the analytic cost path."""
    stream = build_opstream("qwen3-0.6b", SMALL)
    emb = [e for e in stream.entries if e.role == "embed"]
    assert len(emb) == 1
    assert not emb[0].mappable
    assert emb[0].problem.attrs.get("gather") is True
    tasks, _ = stream_sweep_tasks([stream], cloud_accelerator())
    assert all(t.workload.attrs.get("gather") is not True for t in tasks)
    assert len(tasks) == len(stream.mappable_entries())


def test_encoder_only_has_no_decode_stream():
    with pytest.raises(ValueError, match="encoder-only"):
        build_opstream("hubert-xlarge", SMALL_DECODE)


def test_train_backward_factor():
    s_pf = build_opstream("qwen3-0.6b", SMALL)
    s_tr = build_opstream("qwen3-0.6b", SMALL_TRAIN)
    # same tokens/step (128*2 == 4*... no -- compare per-token): train
    # weights every op 3x (fwd + bwd wrt acts + bwd wrt weights)
    assert s_tr.backward_factor == 3.0 and s_pf.backward_factor == 1.0
    per_tok_pf = s_pf.param_flops() / s_pf.meta["tokens_per_step"]
    per_tok_tr = s_tr.param_flops() / s_tr.meta["tokens_per_step"]
    assert per_tok_tr == pytest.approx(3.0 * per_tok_pf)


def test_formula_matches_shapes_convention():
    """formula_model_flops is the 6/2/2 MODEL_FLOPS rule dryrun embeds in
    artifacts (dryrun.model_flops now delegates here)."""
    cfg = get_config("qwen3-0.6b")
    n = cfg.active_params()
    sh = SHAPES["train_4k"]
    assert formula_model_flops(cfg, sh) == 6.0 * n * sh.global_batch * sh.seq_len
    sh = SHAPES["prefill_32k"]
    assert formula_model_flops(cfg, sh) == 2.0 * n * sh.global_batch * sh.seq_len
    sh = SHAPES["decode_32k"]
    assert formula_model_flops(cfg, sh) == 2.0 * n * sh.global_batch


@pytest.mark.parametrize("model", TARGETS)
@pytest.mark.parametrize("shape", ["prefill_32k", "decode_32k"])
def test_full_size_cells_reconcile(model, shape):
    stream = build_opstream(model, shape)
    r = reconcile_model_flops(stream)
    lo, hi = RECONCILE_BAND
    assert lo <= r["ratio"] <= hi, f"{model}/{shape}: {r['ratio']:.3f}"


# --------------------------------------------------------------------- #
# one sweep end-to-end: cross-op sharing + aggregation
# --------------------------------------------------------------------- #
def test_one_sweep_three_families_end_to_end():
    """The acceptance path: dense + MoE + hybrid streams through ONE
    union_opt_sweep call, with cross-op engine/memo sharing reported,
    aggregated to per-model end-to-end latency/energy/EDP."""
    arch = cloud_accelerator()
    streams = [build_opstream(get_config(m + "_smoke"), SMALL) for m in TARGETS]
    tasks, index = stream_sweep_tasks(streams, arch)
    res = union_opt_sweep(tasks)
    assert len(res) == len(tasks)
    # cross-op sharing: content-equal ops across models/layers collapse
    # into shared engine groups, and the shared memo serves repeat
    # signatures -- both must be visibly nonzero
    assert res.stats["engines"] < len(tasks)
    assert res.stats["cache_hits"] > 0
    costs = aggregate_stream_costs(streams, index, res.solutions, arch)
    assert [c.model for c in costs] == [s.model for s in streams]
    for stream, c in zip(streams, costs):
        assert c.latency_s > 0 and c.energy_j > 0
        assert c.edp == pytest.approx(c.energy_j * c.latency_s)
        # role decomposition sums exactly back to the totals
        assert sum(r["latency_s"] for r in c.roles.values()) == pytest.approx(c.latency_s)
        assert sum(r["energy_j"] for r in c.roles.values()) == pytest.approx(c.energy_j)
        # the unmappable embed entry got its analytic bandwidth cost
        assert c.roles["embed"]["latency_s"] > 0
    # MoE stream must carry expert cost, hybrid must carry scan cost
    assert costs[1].roles["moe"]["energy_j"] > 0
    assert costs[2].roles["ssm_scan"]["energy_j"] > 0


def test_collective_term_adds_serial_latency():
    arch = cloud_accelerator()
    streams = [build_opstream(get_config("qwen3-0.6b_smoke"), SMALL)]
    tasks, index = stream_sweep_tasks(streams, arch)
    res = union_opt_sweep(tasks)
    base = aggregate_stream_costs(streams, index, res.solutions, arch)[0]
    coll = aggregate_stream_costs(
        streams, index, res.solutions, arch,
        collective_s={streams[0].model: 1e-3})[0]
    assert coll.collective_s == 1e-3
    assert coll.edp == pytest.approx(coll.energy_j * (base.latency_s + 1e-3))
    assert coll.edp > base.edp


# --------------------------------------------------------------------- #
# dryrun artifact cross-check (skips when artifacts are absent)
# --------------------------------------------------------------------- #
def _load_artifact(model, shape, mesh="16x16"):
    p = ART_DIR / f"{model}__{shape}__{mesh}.json"
    if not p.exists():
        pytest.skip(f"dry-run artifact missing (run repro.launch.dryrun): {p.name}")
    return json.loads(p.read_text())


@pytest.mark.parametrize("model,shape", [(m, s) for m in TARGETS
                                         for s in ("prefill_32k", "decode_32k")])
def test_stream_reconciles_with_dryrun_artifact(model, shape):
    """Stream FLOPs vs the artifact's structure-corrected HLO totals:
    the stream is a lower bound on compiled compute (remat/masking/vector
    work excluded) within dryrun's own useful-FLOPs band (0.05, 1.1]."""
    art = _load_artifact(model, shape)
    stream = build_opstream(model, shape)
    r = reconcile_with_artifact(stream, art)
    assert 0.05 < r["flops_ratio"] <= 1.1, f"{model}/{shape}: {r['flops_ratio']:.3f}"
    # the artifact's embedded MODEL_FLOPS is the same formula we reconcile
    # against (dryrun.model_flops delegates to formula_model_flops)
    assert r["model_flops_artifact"] == pytest.approx(
        formula_model_flops(get_config(model), SHAPES[shape]))
