"""Array-native candidate generation: GenomeBatch + vectorized samplers.

Contracts under test:

  * genome <-> batch ROUND-TRIP: ``GenomeBatch.from_genomes`` /
    ``genome(i)`` / ``signature(i)`` are exact inverses of each other and
    of ``Genome.signature`` (hypothesis-driven over random spaces);
  * DEDUP PARITY: the engine's array-native GenomeBatch path serves the
    exact costs AND counters of the per-candidate list path, across
    scalar/numpy/jax backends, and the canonical key rows collapse ONLY
    rows with bit-identical costs;
  * BATCH LEGALITY == SCALAR LEGALITY: ``chains_legal_batch`` and
    ``constraints_ok_batch`` reproduce ``_chains_legal`` + the
    ``Constraints.check`` verdicts on generated candidates;
  * PER-MAPPER EQUIVALENCE: the exhaustive vectorized enumerator is
    bit-identical (stream, results, counters) to the scalar generator; the
    seed-versioned v2 samplers are deterministic per seed and produce
    bit-identical searches across engine backends; ``seed_version=1``
    reproduces the historical scalar stream.
"""

import random

import numpy as np
import pytest

from repro.core.architecture import cloud_accelerator, edge_accelerator
from repro.core.constraints import (
    Constraints,
    mxu_aligned,
    nvdla_style,
    weight_stationary,
)
from repro.core.cost import MaestroLikeModel, TimeloopLikeModel
from repro.core.cost.engine import EvaluationEngine
from repro.core.mappers import get_mapper
from repro.core.mapspace import MapSpace
from repro.core.optimizer import SweepTask, union_opt, union_opt_sweep
from repro.core.problem import Problem
from repro.core import genome_batch as gbm

GEMM = Problem.gemm(64, 32, 16, word_bytes=1)
CONV = Problem.conv2d(2, 8, 8, 7, 7, 3, 3, stride=2, name="conv_t", word_bytes=1)


def _costs_equal(a, b):
    if a is None or b is None:
        return a is b
    return (
        a.latency_cycles == b.latency_cycles
        and a.energy_pj == b.energy_pj
        and a.utilization == b.utilization
        and a.breakdown == b.breakdown
    )


# --------------------------------------------------------------------- #
# Round-trip
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("problem", [GEMM, CONV], ids=["gemm", "conv"])
@pytest.mark.parametrize(
    "mk_arch", [edge_accelerator, cloud_accelerator], ids=["edge", "cloud"]
)
def test_genome_batch_round_trip(problem, mk_arch):
    space = MapSpace(problem, mk_arch())
    rng = random.Random(3)
    genomes = [space.random_genome(rng) for _ in range(40)]
    gb = gbm.GenomeBatch.from_genomes(space, genomes)
    assert len(gb) == len(genomes)
    for i, g in enumerate(genomes):
        g2 = gb.genome(i)
        assert g2.chains == g.chains
        assert g2.orders == g.orders
        assert gb.signature(i) == g.signature(space.dims)
        # key round-trip: from_genomes(genome(i)) is the same row
        again = gbm.GenomeBatch.from_genomes(space, [g2])
        assert again.row_key(0) == gb.row_key(i)


def test_genome_batch_round_trip_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    space = MapSpace(GEMM, cloud_accelerator())

    @settings(max_examples=30, deadline=None)
    @given(st.integers(min_value=0, max_value=2**31 - 1), st.integers(2, 12))
    def inner(seed, k):
        gb = space.random_genome_batch(gbm.philox_rng(seed), k)
        for i in range(k):
            g = gb.genome(i)
            assert space._chains_legal(g.chains)
            back = gbm.GenomeBatch.from_genomes(space, [g])
            assert back.signature(0) == gb.signature(i)
            assert (back.tt[0] == gb.tt[i]).all()
            assert (back.st[0] == gb.st[i]).all()
            assert (back.perm[0] == gb.perm[i]).all()

    inner()


# --------------------------------------------------------------------- #
# Dedup parity + canonical keys
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", [None, "numpy", "jax"])
@pytest.mark.parametrize(
    "model_cls", [TimeloopLikeModel, MaestroLikeModel], ids=["timeloop", "maestro"]
)
def test_engine_genome_batch_matches_list_path(model_cls, backend):
    if backend == "jax":
        pytest.importorskip("jax")
    arch = cloud_accelerator()
    space = MapSpace(GEMM, arch)
    gb0 = space.random_genome_batch(gbm.philox_rng(1), 120)
    idx = np.concatenate([np.arange(120), np.arange(0, 120, 9)])  # dups
    gb = gb0.select(idx)
    genomes = [gb.genome(i) for i in range(len(gb))]
    cm = model_cls()
    inc = cm.evaluate(GEMM, genomes[0].to_mapping(), arch).metric("edp")
    e_list = EvaluationEngine(model_cls(), GEMM, arch, metric="edp", backend=backend)
    e_gb = EvaluationEngine(model_cls(), GEMM, arch, metric="edp", backend=backend)
    c1 = e_list.evaluate_batch(genomes, incumbent=inc, probe=8)
    c2 = e_gb.evaluate_batch(gb, incumbent=inc, probe=8)
    assert all(_costs_equal(a, b) for a, b in zip(c1, c2))
    for attr in ("evaluated", "cache_hits", "pruned", "considered", "store_hits"):
        assert getattr(e_list.stats, attr) == getattr(e_gb.stats, attr), attr


def test_dedup_array_program_matches_dict_dedup():
    space = MapSpace(GEMM, cloud_accelerator())
    gb0 = space.random_genome_batch(gbm.philox_rng(5), 60)
    idx = np.concatenate([np.arange(60), np.arange(0, 60, 7), np.arange(0, 60, 13)])
    gb = gb0.select(idx)
    rep, inv = gb.dedup()
    # reference: first-occurrence dict over the canonical key bytes
    seen = {}
    ref_rep, ref_inv = [], []
    for b in range(len(gb)):
        k = gb.row_key(b)
        if k not in seen:
            seen[k] = len(ref_rep)
            ref_rep.append(b)
        ref_inv.append(seen[k])
    assert rep.tolist() == ref_rep
    assert inv.tolist() == ref_inv


def test_canonical_keys_collapse_only_cost_identical_rows():
    """Rows sharing a key row MUST have bit-identical costs (the memo
    soundness contract); rows that differ only in inactive-dim order
    placement DO collapse."""
    arch = cloud_accelerator()
    space = MapSpace(GEMM, arch)
    gb = space.random_genome_batch(gbm.philox_rng(11), 200)
    cm = TimeloopLikeModel()
    seen = {}
    for b in range(len(gb)):
        k = gb.key_rows()[b].tobytes()
        c = cm.evaluate(GEMM, gb.genome(b).to_mapping(), arch)
        rec = (c.latency_cycles, c.energy_pj, c.utilization,
               tuple(sorted(c.breakdown.items())))
        if k in seen:
            assert seen[k] == rec
        else:
            seen[k] = rec
    # a synthetic twin pair: all-serial rows where EVERY dim is inactive
    # at inner levels -- permuting inner orders must not change the key
    n, D = space.n_levels, len(space.dims)
    tt = np.ones((2, n, D), dtype=np.int64)
    st = np.ones((2, n, D), dtype=np.int64)
    perm = np.tile(np.arange(D, dtype=np.int64), (2, n, 1))
    perm[1, -1] = perm[1, -1][::-1]  # inner level: all dims inactive
    twins = gbm.GenomeBatch(space, tt, st, perm)
    assert twins.row_key(0) == twins.row_key(1)
    assert (
        cm.evaluate(GEMM, twins.genome(0).to_mapping(), arch).edp
        == cm.evaluate(GEMM, twins.genome(1).to_mapping(), arch).edp
    )


# --------------------------------------------------------------------- #
# Batch legality == scalar legality
# --------------------------------------------------------------------- #
@pytest.mark.parametrize(
    "cons",
    [
        None,
        nvdla_style(("m", "n")),
        Constraints(name="cap1", max_concurrent_spatial=1),
        mxu_aligned(["m"], 8),
        weight_stationary(["k"], cloud_accelerator().clusters[1].name),
        Constraints(name="util", min_utilization=0.01, max_utilization=0.9),
    ],
    ids=["none", "nvdla", "cap1", "mxu", "ws", "util"],
)
def test_batch_legality_matches_scalar(cons):
    arch = cloud_accelerator()
    space = MapSpace(GEMM, arch, cons)
    rng = gbm.philox_rng(3)
    tt, st = gbm.sample_chains_batch(space, rng, 200)
    gbm.repair_fanout_batch(space, rng, tt, st)
    perm, ok = gbm.sample_orders_batch(space, rng, 200)
    assert ok
    gb = gbm.GenomeBatch(space, tt, st, perm)
    legal = gbm.chains_legal_batch(space, tt, st)
    cok = gbm.constraints_ok_batch(space, tt, st, perm)
    for b in range(200):
        g = gb.genome(b)
        assert bool(legal[b]) == space._chains_legal(g.chains), b
        if legal[b] and cons is not None:
            assert bool(cok[b]) == cons.ok(g.to_mapping(), GEMM, arch), b
    # the end-to-end sampler emits only legal rows (or the documented
    # trivial fallback, which the scalar sampler shares)
    ones = (1,) * (2 * arch.n_levels)
    gb2 = space.random_genome_batch(gbm.philox_rng(5), 80)
    for b in range(80):
        g = gb2.genome(b)
        if all(g.chains[d] == ones for d in space.dims):
            continue
        m = g.to_mapping()
        assert m.is_legal(GEMM, arch)
        assert cons is None or cons.ok(m, GEMM, arch)


# --------------------------------------------------------------------- #
# Per-mapper equivalence
# --------------------------------------------------------------------- #
def test_exhaustive_vectorized_bit_identical_to_generator():
    """The mixed-radix decoded stream reproduces the recursive DFS stream
    exactly: same best mapping, same costs, same engine counters."""
    arch = cloud_accelerator()
    for max_mappings in (400, 1100):
        a = union_opt(GEMM, arch, mapper="exhaustive", cost_model="timeloop",
                      max_mappings=max_mappings)
        b = union_opt(GEMM, arch, mapper="exhaustive", cost_model="timeloop",
                      max_mappings=max_mappings, vectorized=False)
        assert a.cost.edp == b.cost.edp
        assert a.mapping.to_dict() == b.mapping.to_dict()
        for attr in ("evaluated", "analyzed", "cache_hits", "pruned", "considered"):
            assert getattr(a.search, attr) == getattr(b.search, attr), attr


@pytest.mark.parametrize("mapper,kw", [
    ("random", {"samples": 300}),
    ("genetic", {"generations": 5}),
    ("decoupled", {"offchip_samples": 80, "onchip_samples": 120}),
])
def test_v2_mappers_deterministic_and_backend_invariant(mapper, kw):
    """seed_version=2 searches: (a) bit-identical across engine backends
    (generation never touches the engine), (b) reproducible per seed,
    (c) seed-sensitive."""
    arch = cloud_accelerator()
    base = union_opt(GEMM, arch, mapper=mapper, cost_model="timeloop", **kw)
    again = union_opt(GEMM, arch, mapper=mapper, cost_model="timeloop", **kw)
    assert base.cost.edp == again.cost.edp
    assert base.mapping.to_dict() == again.mapping.to_dict()
    assert base.search.considered == again.search.considered
    for backend in ("none", "jax"):
        if backend == "jax":
            pytest.importorskip("jax")
        other = union_opt(GEMM, arch, mapper=mapper, cost_model="timeloop",
                          engine_backend=backend, **kw)
        assert base.cost.edp == other.cost.edp, backend
        assert base.mapping.to_dict() == other.mapping.to_dict(), backend
        for attr in ("evaluated", "analyzed", "cache_hits", "pruned",
                     "considered"):
            assert getattr(base.search, attr) == getattr(other.search, attr), (
                backend, attr)
    seeded = union_opt(GEMM, arch, mapper=mapper, cost_model="timeloop",
                       seed=99, **kw)
    assert seeded.search.considered > 0  # a different stream still works


def test_seed_version_1_reproduces_historical_stream():
    """The v1 random sampler must submit EXACTLY the candidates the
    historical per-candidate sampler draws (the explicit seed-version
    contract: v2 is a different, documented stream)."""
    arch = cloud_accelerator()
    space = MapSpace(GEMM, arch)
    rng = random.Random(7)
    expected = [space.random_genome(rng) for _ in range(50)]
    sol = union_opt(GEMM, arch, mapper="random", cost_model="timeloop",
                    samples=50, seed=7, seed_version=1)
    # replay: scoring the expected stream through a fresh engine gives the
    # same best cost/mapping
    eng = EvaluationEngine(TimeloopLikeModel(), GEMM, arch, metric="edp")
    best = min(
        (eng.evaluate(g).metric("edp") for g in expected),
    )
    assert sol.cost.edp == best
    # and v2 differs (seed-versioned stream)
    v2 = union_opt(GEMM, arch, mapper="random", cost_model="timeloop",
                   samples=50, seed=7)
    assert v2.search.considered == sol.search.considered == 50


# --------------------------------------------------------------------- #
# Sweep + warmup
# --------------------------------------------------------------------- #
def test_union_opt_sweep_shares_engines_and_keeps_per_task_stats():
    arch = cloud_accelerator()
    sw = union_opt_sweep([
        SweepTask(GEMM, arch, mapper="heuristic"),
        SweepTask(GEMM, arch, mapper="random", mapper_kw={"samples": 200}),
        SweepTask(CONV, arch, mapper="random", mapper_kw={"samples": 100}),
    ])
    assert sw.stats["engines"] == 2  # GEMM tasks share; CONV separate
    assert len(sw) == 3
    solo = union_opt(GEMM, arch, mapper="heuristic")
    assert sw[0].cost.edp == solo.cost.edp
    assert sw[0].mapping.to_dict() == solo.mapping.to_dict()
    # per-task counters are snapshot diffs, not engine lifetime totals
    assert sw[0].search.considered == solo.search.considered
    solo_r = union_opt(GEMM, arch, mapper="random", samples=200)
    assert sw[1].cost.edp == solo_r.cost.edp
    assert sw[1].search.considered == solo_r.search.considered
    # the shared engine's memo warms the second search: it analyzes no
    # more than a cold engine would
    assert sw[1].search.analyzed <= solo_r.search.analyzed
    assert sw[1].search.cache_hits >= solo_r.search.cache_hits


def test_sweep_content_equal_instances_share_context():
    from repro.core.cost.analysis import get_context

    a1, a2 = cloud_accelerator(), cloud_accelerator()
    p1 = Problem.gemm(48, 24, 12, word_bytes=1)
    p2 = Problem.gemm(48, 24, 12, word_bytes=1)
    assert get_context(p1, a1) is get_context(p2, a2)
    assert get_context(p1, a1) is not get_context(CONV, a1)


def test_bucketed_warmup_pretraces_and_preserves_results():
    pytest.importorskip("jax")
    from repro.core.cost.analysis import get_context, reset_trace_registry

    arch = cloud_accelerator()
    cm = TimeloopLikeModel()
    eng = EvaluationEngine(cm, GEMM, arch, metric="edp", backend="jax")
    ctx = get_context(GEMM, arch)
    # warmup skips buckets the SHAPE CLASS has already traced (any prior
    # engine/test in this process counts), so reset for determinism
    reset_trace_registry()
    before = ctx.jax_dispatches
    n = eng.warmup([6, 100, 3])  # pow2 buckets: 8, 128 (3 < _BATCH_MIN)
    if ctx._jax_failed:
        pytest.skip("jax fused pipeline unavailable")
    assert n == 2
    assert ctx.jax_dispatches - before == 2
    # warmup touches no engine counters and no memo state
    assert eng.stats.considered == 0 and eng.stats.evaluated == 0
    assert len(eng._cache) == 0
    # warmed search == unwarmed search, bit for bit
    cold = union_opt(GEMM, arch, mapper="random", cost_model="timeloop",
                     samples=200, engine_backend="jax")
    space = MapSpace(GEMM, arch)
    res = get_mapper("random", samples=200).search(space, cm, "edp", engine=eng)
    assert res.best_cost.edp == cold.cost.edp
    assert res.best_mapping.to_dict() == cold.mapping.to_dict()
    # numpy engines: warmup is a no-op
    eng_np = EvaluationEngine(cm, GEMM, arch, metric="edp", backend="numpy")
    assert eng_np.warmup([64]) == 0
