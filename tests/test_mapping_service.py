"""Mapping-as-a-service daemon (``repro.serve.mapping_service``):
query parsing + fingerprints, cold->warm byte-identity, deadline-capped
partial answers, nearest-neighbor warm starts, the jax circuit-breaker
recovery cycle, HTTP backpressure, and the two subprocess drills --
SIGTERM graceful drain and kill -9 + restart byte-identity."""

import json
import os
import signal
import subprocess
import sys
import threading
import time
import urllib.error
import urllib.request
from pathlib import Path

import pytest

from repro.core.architecture import edge_accelerator
from repro.core.optimizer import COST_MODEL_REGISTRY
from repro.core.problem import Problem
from repro.serve.mapping_service import (
    MappingService,
    QueryError,
    _ParsedQuery,
    _slice_plan,
    query_fingerprint,
    serve,
)

SRC = str(Path(__file__).resolve().parent.parent / "src")


def _gemm_query(m, n, k, *, budget=120, deadline_s=None, metric="edp",
                name=None, **extra):
    q = {
        "problem": {"kind": "gemm", "m": m, "n": n, "k": k},
        "arch": {"kind": "edge", "aspect": [16, 16]},
        "metric": metric,
        "mapper": {"name": "random", "kw": {"seed": 7}},
        "budget": budget,
    }
    if deadline_s is not None:
        q["deadline_s"] = deadline_s
    if name is not None:
        q["problem"]["name"] = name
    q.update(extra)
    return q


def _rec_bytes(env):
    return json.dumps(env["record"], sort_keys=True).encode()


# ------------------------------------------------------------------ #
# parsing + fingerprints
# ------------------------------------------------------------------ #
def test_query_fingerprint_stable_and_deadline_excluded():
    cm = COST_MODEL_REGISTRY["timeloop"]()
    p = Problem.gemm(64, 32, 16, name="fp-a")
    arch = edge_accelerator(aspect=(16, 16))
    f0 = query_fingerprint(cm, p, arch, "edp", "random", {"seed": 7}, 100)
    assert f0 == query_fingerprint(cm, p, arch, "edp", "random",
                                   {"seed": 7}, 100)
    assert f0 != query_fingerprint(cm, p, arch, "edp", "random",
                                   {"seed": 8}, 100)
    assert f0 != query_fingerprint(cm, p, arch, "latency", "random",
                                   {"seed": 7}, 100)
    assert f0 != query_fingerprint(cm, p, arch, "edp", "random",
                                   {"seed": 7}, 101)
    # display names never affect costs, so they never affect fingerprints
    p2 = Problem.gemm(64, 32, 16, name="fp-OTHER")
    assert f0 == query_fingerprint(cm, p2, arch, "edp", "random",
                                   {"seed": 7}, 100)
    # the deadline shapes search time, not the converged answer
    qa = _ParsedQuery(_gemm_query(64, 32, 16), 5.0)
    qb = _ParsedQuery(_gemm_query(64, 32, 16, deadline_s=0.25), 5.0)
    assert qa.fingerprint == qb.fingerprint
    assert qb.deadline_s == 0.25


@pytest.mark.parametrize(
    "mutate",
    [
        {"problem": {"kind": "wavelet"}},
        {"problem": {"kind": "gemm", "m": 64, "n": 32}},  # k missing
        {"metric": "carbon"},
        {"mapper": "annealing-imaginary"},
        {"budget": "lots"},
        {"deadline_s": -1},
        {"arch": {"kind": "dyson-sphere"}},
        {"model": "no-such-model"},
    ],
    ids=["kind", "missing-dim", "metric", "mapper", "budget", "deadline",
         "arch", "model"],
)
def test_malformed_queries_raise_query_error(mutate):
    q = _gemm_query(64, 32, 16)
    q.update(mutate)
    with pytest.raises(QueryError):
        _ParsedQuery(q, 5.0)


def test_slice_plan_covers_budget_exactly():
    for total in (1, 63, 64, 65, 320, 512, 1000):
        plan = _slice_plan(total)
        assert sum(plan) == total
        assert all(s > 0 for s in plan)
        assert plan[0] <= 64  # a tight deadline still finishes slice 0


# ------------------------------------------------------------------ #
# in-process service: cold -> warm -> restart
# ------------------------------------------------------------------ #
def test_cold_then_warm_then_restart_byte_identical(tmp_path):
    svc = MappingService(str(tmp_path), deadline_s=None)
    q = _gemm_query(64, 48, 32)
    cold = svc.handle_query(q)
    assert cold["ok"] and cold["source"] == "search"
    assert not cold["budget_exhausted"]
    warm = svc.handle_query(q)
    assert warm["ok"] and warm["source"] == "store"
    assert _rec_bytes(warm) == _rec_bytes(cold)
    # same content under a different display name: same answer, no search
    renamed = svc.handle_query(_gemm_query(64, 48, 32, name="alias"))
    assert renamed["source"] == "store"
    assert _rec_bytes(renamed) == _rec_bytes(cold)
    m = svc.metrics()
    assert m["queries"] == 3 and m["store_hits"] == 2 and m["searches"] == 1
    svc.drain()
    # a NEW service on the same state dir answers from the journal alone
    svc2 = MappingService(str(tmp_path), deadline_s=None)
    again = svc2.handle_query(q)
    assert again["source"] == "store"
    assert _rec_bytes(again) == _rec_bytes(cold)
    assert svc2.metrics()["searches"] == 0


def test_error_envelope_not_exception(tmp_path):
    svc = MappingService(str(tmp_path))
    env = svc.handle_query({"problem": {"kind": "wavelet"}})
    assert env["ok"] is False and "wavelet" in env["error"]
    assert svc.metrics()["errors"] == 1
    assert svc.metrics()["queries"] == 0  # rejected before admission


# ------------------------------------------------------------------ #
# deadlines: partial answers, never errors
# ------------------------------------------------------------------ #
def test_tiny_deadline_returns_flagged_fallback(tmp_path):
    svc = MappingService(str(tmp_path))
    env = svc.handle_query(_gemm_query(96, 96, 96, budget=5000,
                                       deadline_s=1e-4))
    assert env["ok"] is True
    assert env["budget_exhausted"] is True
    assert env["record"]["mapping"]  # an incumbent, not an error
    assert env["record"]["cost"]  # a scored Cost record rides along
    m = svc.metrics()
    assert m["partials"] == 1 and m["fallback_answers"] == 1
    # partial answers are NOT journaled: the query stays cold
    again = svc.handle_query(_gemm_query(96, 96, 96, budget=5000,
                                         deadline_s=None))
    assert again["source"] == "search" and not again["budget_exhausted"]


def test_slow_injection_yields_partial_with_real_incumbent(tmp_path):
    """``slow:0@1:30`` stalls budget slice 1 of cold search 0; with a
    ~1s deadline the answer is slice 0's real incumbent, flagged
    exhausted -- the deadline path fires without any wall-clock
    guesswork."""
    svc = MappingService(str(tmp_path), fault_spec="slow:0@1:30")
    env = svc.handle_query(_gemm_query(80, 80, 40, budget=512,
                                       deadline_s=1.0))
    assert env["ok"] is True and env["budget_exhausted"] is True
    assert env["record"]["counters"]["considered"] >= 64  # slice 0 ran
    m = svc.metrics()
    assert m["partials"] == 1
    assert m["fallback_answers"] == 0  # real incumbent, not the fallback
    # a re-ask without the deadline converges and journals normally
    done = svc.handle_query(_gemm_query(80, 80, 40, budget=512,
                                        deadline_s=None))
    assert done["source"] == "search" and not done["budget_exhausted"]
    assert svc.handle_query(
        _gemm_query(80, 80, 40, budget=512)
    )["source"] == "store"


# ------------------------------------------------------------------ #
# nearest-neighbor warm starts
# ------------------------------------------------------------------ #
def test_neighbor_seed_fires_and_result_matches_unseeded(tmp_path):
    svc = MappingService(str(tmp_path), deadline_s=None)
    first = svc.handle_query(_gemm_query(64, 64, 64))
    assert first["seeded"] is False  # nothing registered yet
    near = svc.handle_query(_gemm_query(64, 64, 48))
    assert near["seeded"] is True
    assert near["neighbor"]["distance"] >= 0.0
    m = svc.metrics()
    assert m["seeded"] == 1 and m["neighbor_hits"] == 1
    assert m["neighbor_misses"] == 1
    # seeding is a pruning accelerant, never an answer-changer: the same
    # query against a fresh (seedless) state dir finds the same best
    lone = MappingService(str(tmp_path / "lone"), deadline_s=None)
    ref = lone.handle_query(_gemm_query(64, 64, 48))
    assert near["record"]["cost"] == ref["record"]["cost"]
    assert near["record"]["mapping"] == ref["record"]["mapping"]


# ------------------------------------------------------------------ #
# circuit breaker: open -> half-open -> closed under injected jax faults
# ------------------------------------------------------------------ #
def test_breaker_opens_degrades_and_recovers(tmp_path):
    svc = MappingService(
        str(tmp_path), backend="jax", deadline_s=None,
        breaker_threshold=2, probe_interval=2,
        fault_spec="jaxfail:0;jaxfail:1",
    )
    envs = [svc.handle_query(_gemm_query(32 + 16 * i, 32, 32, budget=96))
            for i in range(4)]
    assert all(e["ok"] for e in envs)
    br = svc.metrics()["breaker"]
    assert br["transitions"] == [
        "closed->open", "open->half_open", "half_open->closed"
    ]
    assert br["state"] == "closed"
    assert br["opened"] == 1 and br["recovered"] == 1
    # queries 0/1 degraded mid-search; 2 was denied jax (circuit open);
    # 3 was the half-open probe that ran clean and closed the circuit
    assert envs[0]["backend"] == "numpy"
    assert envs[2]["backend"] == "numpy"
    assert envs[3]["backend"] == "jax"


def test_breaker_open_answers_stay_available_numpy(tmp_path):
    """With the circuit held open (every query's jax poisoned), answers
    keep flowing on the numpy path -- degradation is invisible to the
    caller apart from the advertised backend."""
    svc = MappingService(
        str(tmp_path), backend="jax", deadline_s=None, breaker_threshold=1,
        probe_interval=100, fault_spec=";".join(f"jaxfail:{i}"
                                                for i in range(4)),
    )
    for i in range(4):
        env = svc.handle_query(_gemm_query(48 + 16 * i, 32, 32, budget=96))
        assert env["ok"] and env["record"]["mapping"]
    br = svc.metrics()["breaker"]
    assert br["state"] == "open" and br["denied"] >= 1


# ------------------------------------------------------------------ #
# HTTP front: round-trip, 400, and deterministic 429 backpressure
# ------------------------------------------------------------------ #
def _post(port, payload, timeout=60.0):
    req = urllib.request.Request(
        f"http://127.0.0.1:{port}/v1/mapping",
        data=json.dumps(payload).encode(),
        headers={"Content-Type": "application/json"},
    )
    try:
        with urllib.request.urlopen(req, timeout=timeout) as r:
            return r.status, json.loads(r.read()), dict(r.headers)
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read()), dict(e.headers)


def test_http_round_trip_and_metrics(tmp_path):
    svc = MappingService(str(tmp_path), deadline_s=None, workers=1)
    httpd = serve(svc)
    port = httpd.server_address[1]
    th = threading.Thread(target=httpd.serve_forever, daemon=True)
    th.start()
    try:
        st, env, _ = _post(port, _gemm_query(64, 32, 32))
        assert st == 200 and env["ok"] and env["source"] == "search"
        st, warm, _ = _post(port, _gemm_query(64, 32, 32))
        assert st == 200 and warm["source"] == "store"
        assert _rec_bytes(warm) == _rec_bytes(env)
        st, bad, _ = _post(port, {"problem": {"kind": "wavelet"}})
        assert st == 400 and bad["ok"] is False
        with urllib.request.urlopen(
            f"http://127.0.0.1:{port}/metrics", timeout=30
        ) as r:
            m = json.loads(r.read())
        assert m["queries"] == 2 and m["store_hits"] == 1
        with urllib.request.urlopen(
            f"http://127.0.0.1:{port}/healthz", timeout=30
        ) as r:
            assert json.loads(r.read()) == {"ok": True, "draining": False}
    finally:
        httpd.shutdown()
        svc.drain()


def test_http_queue_full_sheds_with_retry_after(tmp_path):
    """Deterministic backpressure: no workers running yet, queue cap 1 --
    the first POST parks in the queue, the second MUST be shed with 429 +
    Retry-After. Workers are then started so the parked job completes."""
    svc = MappingService(str(tmp_path), deadline_s=None, queue_cap=1,
                         workers=1)
    from repro.serve.mapping_service import _make_handler
    from http.server import ThreadingHTTPServer

    httpd = ThreadingHTTPServer(("127.0.0.1", 0), _make_handler(svc))
    httpd.daemon_threads = True
    port = httpd.server_address[1]
    th = threading.Thread(target=httpd.serve_forever, daemon=True)
    th.start()
    first = {}

    def poster():
        first["out"] = _post(port, _gemm_query(64, 32, 32), timeout=180.0)

    pt = threading.Thread(target=poster, daemon=True)
    pt.start()
    deadline = time.monotonic() + 10.0
    while svc.jobs.qsize() < 1 and time.monotonic() < deadline:
        time.sleep(0.01)
    assert svc.jobs.qsize() == 1
    st, env, headers = _post(port, _gemm_query(48, 32, 32))
    assert st == 429
    assert env["error"] == "admission queue full"
    assert headers.get("Retry-After") == "1"
    assert svc.metrics()["shed"] == 1
    svc.start_workers()  # release the parked job
    pt.join(timeout=120.0)
    assert not pt.is_alive()
    st, env, _ = first["out"]
    assert st == 200 and env["ok"]
    httpd.shutdown()
    svc.drain()


# ------------------------------------------------------------------ #
# subprocess drills: SIGTERM drain, kill -9 + restart byte-identity
# ------------------------------------------------------------------ #
def _spawn_daemon(state_dir, *extra_args, timeout_s=60.0):
    ready = os.path.join(state_dir, "ready.json")
    if os.path.exists(ready):  # stale file from a previous incarnation
        os.unlink(ready)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [SRC] + [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p]
    )
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.serve.mapping_service",
         "--state-dir", str(state_dir), "--ready-file", ready,
         "--deadline-s", "0", *extra_args],
        env=env,
    )
    t0 = time.monotonic()
    while time.monotonic() - t0 < timeout_s:
        if os.path.exists(ready):
            with open(ready) as f:
                return proc, json.load(f)["port"]
        if proc.poll() is not None:
            raise AssertionError(f"daemon died at startup rc={proc.returncode}")
        time.sleep(0.05)
    proc.kill()
    raise AssertionError("daemon never became ready")


def test_sigterm_drains_inflight_query_and_exits_zero(tmp_path):
    """The graceful half of crash safety: SIGTERM while a cold query is
    in flight -- the query is still answered AND journaled (a restarted
    daemon serves it warm), and the daemon exits 0."""
    proc, port = _spawn_daemon(tmp_path)
    q = _gemm_query(72, 72, 36, budget=400)
    out = {}

    def poster():
        out["resp"] = _post(port, q, timeout=120.0)

    pt = threading.Thread(target=poster, daemon=True)
    pt.start()
    time.sleep(0.15)  # let the POST be admitted
    proc.send_signal(signal.SIGTERM)
    pt.join(timeout=120.0)
    assert not pt.is_alive()
    st, env, _ = out["resp"]
    assert st == 200 and env["ok"], env
    assert proc.wait(timeout=60.0) == 0  # clean drain exit

    proc2, port2 = _spawn_daemon(tmp_path)
    try:
        st, warm, _ = _post(port2, q)
        assert st == 200 and warm["source"] == "store"
        assert _rec_bytes(warm) == _rec_bytes(env)
    finally:
        proc2.send_signal(signal.SIGTERM)
        assert proc2.wait(timeout=60.0) == 0


def test_kill9_restart_answers_byte_identical_from_store(tmp_path):
    """The acceptance drill: answer queries, kill -9 the daemon, restart
    on the same state dir -- every previously-answered query must come
    back byte-identical from the journal with ZERO re-search
    (store_hits == queries)."""
    proc, port = _spawn_daemon(tmp_path)
    queries = [_gemm_query(64 + 16 * i, 64, 32, budget=150) for i in range(3)]
    before = []
    for q in queries:
        st, env, _ = _post(port, q, timeout=120.0)
        assert st == 200 and env["ok"] and env["source"] == "search"
        before.append(env)
    proc.kill()  # SIGKILL: no drain, no atexit, nothing graceful
    assert proc.wait(timeout=30.0) == -signal.SIGKILL

    proc2, port2 = _spawn_daemon(tmp_path)
    try:
        for q, old in zip(queries, before):
            st, env, _ = _post(port2, q, timeout=120.0)
            assert st == 200 and env["source"] == "store"
            assert _rec_bytes(env) == _rec_bytes(old)
        with urllib.request.urlopen(
            f"http://127.0.0.1:{port2}/metrics", timeout=30
        ) as r:
            m = json.loads(r.read())
        assert m["queries"] == len(queries)
        assert m["store_hits"] == m["queries"]  # zero re-search
        assert m["searches"] == 0
        assert m["journal"]["resumed"] is True
    finally:
        proc2.send_signal(signal.SIGTERM)
        assert proc2.wait(timeout=60.0) == 0
