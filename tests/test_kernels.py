"""Per-kernel shape/dtype sweeps: every Pallas kernel (interpret=True)
against its pure-jnp ref.py oracle, plus the Union tile-planner contracts."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.architecture import tpu_chip
from repro.core.problem import Problem
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ops import plan_blocks
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.matmul import matmul, plan_tiles, tiles_from_mapping
from repro.kernels.matmul.ref import matmul_ref
from repro.kernels.ssd_scan import ssd_chunked
from repro.kernels.ssd_scan.ops import plan_chunk
from repro.kernels.ssd_scan.ref import ssd_chunked_ref, ssd_recurrent_ref

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------------------ #
# matmul
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "m,n,k", [(128, 128, 128), (256, 128, 384), (300, 200, 100), (64, 512, 256), (1, 257, 33)]
)
def test_matmul_sweep(m, n, k, dtype):
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (m, k), jnp.float32).astype(dtype)
    y = jax.random.normal(ks[1], (k, n), jnp.float32).astype(dtype)
    got = matmul(x, y, interpret=True)
    ref = matmul_ref(x, y)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        got.astype(jnp.float32), ref.astype(jnp.float32), rtol=tol, atol=tol
    )


def test_matmul_batched_lead_dims():
    x = jax.random.normal(KEY, (2, 3, 64, 32))
    y = jax.random.normal(jax.random.PRNGKey(1), (32, 48))
    got = matmul(x, y, interpret=True)
    np.testing.assert_allclose(got, x @ y, rtol=2e-5, atol=2e-5)


def test_matmul_grad_matches():
    x = jax.random.normal(KEY, (128, 64))
    y = jax.random.normal(jax.random.PRNGKey(1), (64, 128))
    gx, gy = jax.grad(lambda a, b: matmul(a, b, interpret=True).sum(), (0, 1))(x, y)
    rx, ry = jax.grad(lambda a, b: (a @ b).sum(), (0, 1))(x, y)
    np.testing.assert_allclose(gx, rx, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(gy, ry, rtol=2e-5, atol=2e-5)


def test_plan_tiles_fit_vmem_and_align():
    """The Union mapping legality (rule R3) IS the BlockSpec validity."""
    for (M, N, K) in [(4096, 4096, 4096), (8192, 1024, 512), (128, 128, 128)]:
        bm, bn, bk = plan_tiles(M, N, K)
        assert M % bm == 0 and N % bn == 0 and K % bk == 0
        ws = 2 * (bm * bk + bk * bn) + 4 * bm * bn  # bf16 in, f32 acc
        assert ws <= 2 * tpu_chip().clusters[-1].memory_bytes  # double-buffer budget
        for b in (bm, bn, bk):
            assert b % 128 == 0 or b in (M, N, K)


def test_tiles_from_mapping_reads_leaf_level():
    from repro.core.optimizer import union_opt
    from repro.core.constraints import mxu_aligned

    p = Problem.gemm(1024, 1024, 1024)
    sol = union_opt(p, tpu_chip(), mapper="heuristic", cost_model="timeloop",
                    metric="latency", constraints=mxu_aligned(["m", "n", "k"]))
    bm, bn, bk = tiles_from_mapping(sol.mapping, p)
    assert bm == sol.mapping.levels[-1].tt("m")


# ------------------------------------------------------------------ #
# flash attention
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,sq,skv,hq,hkv,d,causal",
    [
        (2, 128, 128, 4, 4, 64, True),
        (2, 128, 128, 8, 2, 64, True),    # GQA 4:1
        (1, 256, 256, 4, 1, 32, True),    # MQA
        (2, 64, 192, 4, 2, 64, False),    # bidirectional, cross-length
        (1, 100, 100, 2, 2, 16, True),    # non-divisible -> padded
    ],
)
def test_flash_attention_sweep(b, sq, skv, hq, hkv, d, causal, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, sq, hq, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, skv, hkv, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, skv, hkv, d), jnp.float32).astype(dtype)
    got = flash_attention(q, k, v, causal=causal, blocks=(64, 64), interpret=True)
    ref = jnp.swapaxes(
        attention_ref(
            jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
            causal=causal, scale=1.0 / math.sqrt(d),
        ), 1, 2,
    )
    tol = 2e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        got.astype(jnp.float32), ref.astype(jnp.float32), rtol=tol, atol=tol
    )


def test_flash_decode_kv_len_mask():
    """Decode: 1 query over a 512-slot cache with only 300 valid entries."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 1, 8, 64))
    k = jax.random.normal(ks[1], (2, 512, 2, 64))
    v = jax.random.normal(ks[2], (2, 512, 2, 64))
    got = flash_attention(q, k, v, causal=False, q_offset=299,
                          kv_len=jnp.int32(300), blocks=(8, 128), interpret=True)
    ref = jnp.swapaxes(
        attention_ref(
            jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
            causal=False, scale=1.0 / math.sqrt(64),
            q_offset=299, kv_len=jnp.int32(300),
        ), 1, 2,
    )
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)
    # changing masked-out cache slots must not change the output
    k2 = k.at[:, 300:].set(99.0)
    got2 = flash_attention(q, k2, v, causal=False, q_offset=299,
                           kv_len=jnp.int32(300), blocks=(8, 128), interpret=True)
    np.testing.assert_allclose(got, got2, rtol=1e-6, atol=1e-6)


def test_flash_matches_model_mha():
    from repro.models.layers import mha

    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 128, 8, 32))
    k = jax.random.normal(ks[1], (2, 128, 2, 32))
    v = jax.random.normal(ks[2], (2, 128, 2, 32))
    ref = mha(q, k, v, causal=True, q_chunk=64)
    got = flash_attention(q, k, v, causal=True, blocks=(64, 64), interpret=True)
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)


def test_plan_blocks_contract():
    bq, bk = plan_blocks(4096, 4096, 128)
    assert 4096 % bq == 0 and 4096 % bk == 0
    assert bq >= 128 and bk >= 128
    # f32 score block within the 8MB budget handed to the planner
    assert 4 * bq * bk <= 8 * (1 << 20)


# ------------------------------------------------------------------ #
# SSD scan
# ------------------------------------------------------------------ #
@pytest.mark.parametrize(
    "b,l,nh,hp,n,chunk",
    [(2, 128, 3, 16, 8, 32), (1, 64, 2, 8, 4, 64), (2, 96, 1, 32, 16, 16)],
)
def test_ssd_sweep(b, l, nh, hp, n, chunk):
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (b, l, nh, hp)) * 0.5
    dA = -jax.nn.softplus(jax.random.normal(ks[1], (b, l, nh)))
    B = jax.random.normal(ks[2], (b, l, nh, n)) * 0.5
    C = jax.random.normal(ks[3], (b, l, nh, n)) * 0.5
    y_k, S_k = ssd_chunked(x, dA, B, C, chunk=chunk, interpret=True)
    y_r, S_r = ssd_recurrent_ref(x, dA, B, C)
    np.testing.assert_allclose(y_k, y_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(S_k, S_r, rtol=1e-4, atol=1e-4)


def test_ssd_chunk_invariance():
    """Chunk size is a pure performance knob -- results identical."""
    ks = jax.random.split(KEY, 4)
    b, l, nh, hp, n = 1, 128, 2, 8, 4
    x = jax.random.normal(ks[0], (b, l, nh, hp)) * 0.5
    dA = -jax.nn.softplus(jax.random.normal(ks[1], (b, l, nh)))
    B = jax.random.normal(ks[2], (b, l, nh, n)) * 0.5
    C = jax.random.normal(ks[3], (b, l, nh, n)) * 0.5
    y16, _ = ssd_chunked(x, dA, B, C, chunk=16, interpret=True)
    y64, _ = ssd_chunked(x, dA, B, C, chunk=64, interpret=True)
    np.testing.assert_allclose(y16, y64, rtol=1e-4, atol=1e-4)


def test_ssd_grads_match_ref():
    ks = jax.random.split(KEY, 4)
    b, l, nh, hp, n = 1, 64, 2, 8, 4
    x = jax.random.normal(ks[0], (b, l, nh, hp)) * 0.5
    dA = -jax.nn.softplus(jax.random.normal(ks[1], (b, l, nh)))
    B = jax.random.normal(ks[2], (b, l, nh, n)) * 0.5
    C = jax.random.normal(ks[3], (b, l, nh, n)) * 0.5
    gk = jax.grad(lambda *a: ssd_chunked(*a, chunk=32, interpret=True)[0].sum(),
                  (0, 1, 2, 3))(x, dA, B, C)
    gr = jax.grad(lambda *a: ssd_chunked_ref(*a, chunk=32)[0].sum(),
                  (0, 1, 2, 3))(x, dA, B, C)
    for a, r in zip(gk, gr):
        np.testing.assert_allclose(a, r, rtol=1e-4, atol=1e-4)


def test_plan_chunk_vmem_bound():
    for hp, n in [(64, 128), (64, 64), (256, 64)]:
        cl = plan_chunk(hp, n)
        assert 4 * (2 * cl * cl + cl * (hp + 2 * n + 2) + n * hp) <= 8 * (1 << 20)
        assert cl >= 64
