"""Fully-batched admission pipeline + persistent result store.

The vectorized lower bound must be BIT-identical to the scalar bound for
every cost model (values, admit/reject decisions, and engine counters),
the single-dispatch fused jax admit+score program must be bit-identical
to the numpy and scalar paths (costs, decisions, counters) while issuing
exactly ONE jitted dispatch per miss-batch, the engine-level probe warm
start must never change results, and the cross-search ResultStore must
round-trip Costs exactly, survive corrupt or version-mismatched disk
files, evict LRU entries at its per-space cap, keep concurrent flushes
lossless up to that cap, and leave search outputs unchanged on warm runs.
"""

import dataclasses
import json
import math
import random

import numpy as np
import pytest

from repro.core.architecture import (
    cloud_accelerator,
    edge_accelerator,
    tpu_v5e_pod,
)
from repro.core.cost import (
    EvaluationEngine,
    MaestroLikeModel,
    ResultStore,
    TimeloopLikeModel,
    TPURooflineModel,
)
from repro.core.cost.analysis import get_context
from repro.core.cost.store import STORE_VERSION, space_key
from repro.core.optimizer import union_opt
from repro.core.mapspace import MapSpace
from repro.core.problem import Problem

GEMM = Problem.gemm(64, 32, 16, word_bytes=1)
CONV = Problem.conv2d(2, 8, 8, 7, 7, 3, 3, stride=2, name="conv_t", word_bytes=1)
MODELS = [TimeloopLikeModel, MaestroLikeModel, TPURooflineModel]


def _costs_equal(a, b):
    return (
        a.latency_cycles == b.latency_cycles
        and a.energy_pj == b.energy_pj
        and a.utilization == b.utilization
        and a.macs == b.macs
        and a.frequency_hz == b.frequency_hz
        and a.breakdown == b.breakdown
    )


# --------------------------------------------------------------------- #
# Batched lower bound == scalar lower bound
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("problem", [GEMM, CONV], ids=["gemm", "conv"])
@pytest.mark.parametrize("model_cls", MODELS)
@pytest.mark.parametrize(
    "mk_arch",
    [edge_accelerator, cloud_accelerator, lambda: tpu_v5e_pod(1, 2, 2)],
    ids=["edge", "cloud", "tpu_pod"],
)
def test_lower_bound_batch_bit_identical(problem, model_cls, mk_arch):
    """lower_bound_batch_fn == lower_bound_fn per signature, bit for bit,
    for all three cost models on every architecture family."""
    arch = mk_arch()
    cm = model_cls()
    ctx = get_context(problem, arch)
    space = MapSpace(problem, arch)
    rng = random.Random(3)
    sigs = [space.random_genome(rng).signature(ctx.dims) for _ in range(60)]
    batch_fn = cm.lower_bound_batch_fn(problem, arch)
    assert batch_fn is not None
    lb = batch_fn(sigs)
    assert lb is not None
    cyc, en = lb
    assert cyc.dtype == np.float64 and en.dtype == np.float64
    scalar_fn = cm.lower_bound_fn(problem, arch)
    for i, sig in enumerate(sigs):
        sc, se = scalar_fn(sig)
        assert float(sc) == cyc[i]
        assert float(se) == en[i]


def test_lower_bound_batch_hypothesis_equivalence():
    """Randomized GEMM shapes x seeds: batched bound == scalar bound."""
    pytest.importorskip(
        "hypothesis", reason="hypothesis not installed (see requirements-dev.txt)"
    )
    from hypothesis import given, settings, strategies as st

    sizes = st.sampled_from([1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64])

    @given(sizes, sizes, sizes, st.integers(0, 2**20))
    @settings(max_examples=25, deadline=None)
    def check(M, N, K, seed):
        problem = Problem.gemm(M, N, K, word_bytes=1)
        arch = cloud_accelerator()
        ctx = get_context(problem, arch)
        space = MapSpace(problem, arch)
        rng = random.Random(seed)
        sigs = [space.random_genome(rng).signature(ctx.dims) for _ in range(6)]
        for cm in (TimeloopLikeModel(), MaestroLikeModel(), TPURooflineModel()):
            lb = cm.lower_bound_batch_fn(problem, arch)(sigs)
            assert lb is not None
            scalar_fn = cm.lower_bound_fn(problem, arch)
            for i, sig in enumerate(sigs):
                sc, se = scalar_fn(sig)
                assert float(sc) == lb[0][i]
                assert float(se) == lb[1][i]

    check()


def test_lower_bound_batch_jax_matches_numpy():
    """The jitted JAX lower-bound core produces the same arrays as numpy
    (device-resident StackedBatch shared with the traffic program)."""
    pytest.importorskip("jax")
    arch = cloud_accelerator()
    ctx = get_context(GEMM, arch)
    space = MapSpace(GEMM, arch)
    rng = random.Random(7)
    sigs = [space.random_genome(rng).signature(ctx.dims) for _ in range(13)]
    lb_np = ctx.lower_bound_batch(sigs, backend="numpy")
    sb = ctx.stacked_batch(sigs)
    lb_jax = ctx.lower_bound_batch(backend="jax", stacked=sb)
    if ctx._jax_failed:
        pytest.skip("jax lb core unavailable on this platform")
    assert np.array_equal(lb_np[0], lb_jax[0])
    assert np.array_equal(lb_np[1], lb_jax[1])
    # the uploaded matrices stay on the handle for the scoring pass
    assert sb.dev is not None
    bt_dev = ctx.signature_traffic_batch(backend="jax", stacked=sb, select=[0, 2, 5])
    bt_np = ctx.signature_traffic_batch([sigs[i] for i in (0, 2, 5)], backend="numpy")
    assert np.array_equal(bt_dev.compute_cycles, bt_np.compute_cycles)
    for rd, rn in zip(bt_dev.rows, bt_np.rows):
        for a, b in zip(rd, rn):
            assert np.array_equal(a, b)


def test_admit_decisions_and_counters_match_scalar_path():
    """Full searches through the batched admission filter == the scalar
    per-candidate filter: same best mapping/cost AND same counters, across
    the mapper x cost-model matrix."""
    arch = cloud_accelerator()
    matrix = [
        ("random", "timeloop", {"samples": 400}),
        ("random", "maestro", {"samples": 400}),
        ("exhaustive", "timeloop", {"max_mappings": 600}),
        ("exhaustive", "maestro", {"max_mappings": 600}),
        ("decoupled", "timeloop", {"offchip_samples": 80, "onchip_samples": 120}),
        ("heuristic", "timeloop", {}),
    ]
    for mapper, cm, kw in matrix:
        a = union_opt(GEMM, arch, mapper=mapper, cost_model=cm,
                      engine_backend="numpy", **kw)
        b = union_opt(GEMM, arch, mapper=mapper, cost_model=cm,
                      engine_backend="none", **kw)
        assert a.cost.edp == b.cost.edp, (mapper, cm)
        assert a.mapping.to_dict() == b.mapping.to_dict(), (mapper, cm)
        for attr in ("evaluated", "analyzed", "cache_hits", "pruned", "store_hits"):
            assert getattr(a.search, attr) == getattr(b.search, attr), (mapper, cm, attr)


def test_engine_probe_param_identical_results():
    """The engine-level probe warm start changes counters, never results."""
    arch = cloud_accelerator()
    cm = TimeloopLikeModel()
    space = MapSpace(GEMM, arch)
    rng = random.Random(5)
    batch = [space.random_genome(rng) for _ in range(64)]
    plain = EvaluationEngine(cm, GEMM, arch, metric="edp")
    probed = EvaluationEngine(cm, GEMM, arch, metric="edp")
    want = plain.evaluate_batch(batch, incumbent=math.inf)
    got = probed.evaluate_batch(batch, incumbent=math.inf, probe=8)
    # no incumbent given: plain evaluates everything; probed may prune
    # candidates that provably cannot beat the head's best -- every
    # non-None cost must agree, and the head must be fully scored
    assert all(c is not None for c in got[:8])
    for a, b in zip(got, want):
        if a is not None:
            assert _costs_equal(a, b)
    assert probed.stats.pruned > 0  # the warm start engaged the filter


# --------------------------------------------------------------------- #
# Single-dispatch fused admit+score (jax backend)
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("model_cls", MODELS)
def test_fused_single_dispatch_per_miss_batch(model_cls):
    """Under engine_backend='jax', ONE jitted dispatch covers admit+score
    for a whole miss-batch (dispatch-count probe on the context), and the
    resulting Costs/decisions are served without further array programs."""
    pytest.importorskip("jax")
    arch = cloud_accelerator()
    cm = model_cls()
    ctx = get_context(GEMM, arch)
    space = MapSpace(GEMM, arch)
    rng = random.Random(9)
    batch = [space.random_genome(rng) for _ in range(64)]
    eng = EvaluationEngine(cm, GEMM, arch, metric="edp", backend="jax")
    inc = eng.evaluate(batch[0]).metric("edp")
    before = ctx.jax_dispatches
    costs = eng.evaluate_batch(batch, incumbent=inc)
    if ctx._jax_failed:
        pytest.skip("jax fused core unavailable on this platform")
    assert ctx.jax_dispatches - before == 1
    assert eng.stats.fused_dispatches == 1
    assert any(c is not None for c in costs)
    # a second batch reuses the jitted program: still one dispatch each
    batch2 = [space.random_genome(rng) for _ in range(32)]
    eng.evaluate_batch(batch2, incumbent=inc)
    assert ctx.jax_dispatches - before == 2
    assert eng.stats.fused_dispatches == 2


def test_fused_jax_matches_numpy_and_scalar_searches():
    """Full searches under the fused jax single-dispatch pipeline produce
    bit-identical best costs, mappings, AND counters vs the numpy and
    scalar engines, across the mapper x cost-model matrix."""
    pytest.importorskip("jax")
    arch = cloud_accelerator()
    matrix = [
        ("random", "timeloop", {"samples": 400}),
        ("random", "maestro", {"samples": 400}),
        ("exhaustive", "timeloop", {"max_mappings": 600}),
        ("genetic", "maestro", {"generations": 6}),
        ("heuristic", "timeloop", {}),
        ("decoupled", "timeloop", {"offchip_samples": 80, "onchip_samples": 120}),
    ]
    for mapper, cm, kw in matrix:
        a = union_opt(GEMM, arch, mapper=mapper, cost_model=cm,
                      engine_backend="jax", **kw)
        b = union_opt(GEMM, arch, mapper=mapper, cost_model=cm,
                      engine_backend="numpy", **kw)
        c = union_opt(GEMM, arch, mapper=mapper, cost_model=cm,
                      engine_backend="none", **kw)
        if get_context(GEMM, arch)._jax_failed:
            pytest.skip("jax unavailable for the fused pipeline")
        assert a.cost.edp == b.cost.edp == c.cost.edp, (mapper, cm)
        assert _costs_equal(a.cost, b.cost) and _costs_equal(b.cost, c.cost)
        assert (
            a.mapping.to_dict() == b.mapping.to_dict() == c.mapping.to_dict()
        ), (mapper, cm)
        for attr in (
            "evaluated", "analyzed", "cache_hits", "pruned", "store_hits",
            "considered",
        ):
            assert (
                getattr(a.search, attr)
                == getattr(b.search, attr)
                == getattr(c.search, attr)
            ), (mapper, cm, attr)
        assert a.search.fused_dispatches > 0, (mapper, cm)


def test_fused_tpu_roofline_on_pod():
    """The roofline model's own admission bound drives the fused program
    on a TPU-pod architecture, bit-identically to the numpy flow."""
    pytest.importorskip("jax")
    arch = tpu_v5e_pod(1, 2, 2)
    a = union_opt(GEMM, arch, mapper="random", cost_model="tpu_roofline",
                  engine_backend="jax", samples=300)
    b = union_opt(GEMM, arch, mapper="random", cost_model="tpu_roofline",
                  engine_backend="numpy", samples=300)
    if get_context(GEMM, arch)._jax_failed:
        pytest.skip("jax unavailable for the fused pipeline")
    assert _costs_equal(a.cost, b.cost)
    assert a.mapping.to_dict() == b.mapping.to_dict()
    assert a.search.pruned == b.search.pruned
    assert a.search.analyzed == b.search.analyzed


# --------------------------------------------------------------------- #
# ResultStore
# --------------------------------------------------------------------- #
def test_store_roundtrip_and_flush(tmp_path):
    arch = edge_accelerator()
    cm = TimeloopLikeModel()
    ctx = get_context(GEMM, arch)
    space = MapSpace(GEMM, arch)
    rng = random.Random(0)
    sigs = [space.random_genome(rng).signature(ctx.dims) for _ in range(5)]
    skey = space_key(cm, GEMM, arch)
    store = ResultStore(tmp_path / "store")
    costs = {sig: cm.evaluate_signature(GEMM, arch, sig) for sig in sigs}
    for sig, c in costs.items():
        store.put(skey, sig, c)
    assert store.puts == len(costs)
    assert store.flush() == len(costs)
    # a fresh instance reads the disk tier lazily and returns EXACT Costs
    fresh = ResultStore(tmp_path / "store")
    for sig, c in costs.items():
        got = fresh.get(skey, sig)
        assert got is not None and _costs_equal(got, c)
    assert fresh.hits == len(costs) and fresh.disk_loaded == len(costs)
    assert fresh.get(skey, ("missing",)) is None
    assert fresh.misses == 1


def test_store_version_mismatch_and_corruption(tmp_path):
    arch = edge_accelerator()
    cm = TimeloopLikeModel()
    ctx = get_context(GEMM, arch)
    g = MapSpace(GEMM, arch).random_genome(random.Random(1))
    sig = g.signature(ctx.dims)
    skey = space_key(cm, GEMM, arch)
    cost = cm.evaluate_signature(GEMM, arch, sig)

    store = ResultStore(tmp_path)
    store.put(skey, sig, cost)
    store.flush()
    f = tmp_path / f"{skey}.json"
    assert f.exists()

    # version mismatch: entries are discarded (counted), not raised
    payload = json.loads(f.read_text())
    payload["version"] = STORE_VERSION + 1
    f.write_text(json.dumps(payload))
    stale = ResultStore(tmp_path)
    assert stale.get(skey, sig) is None
    assert stale.corrupt == 1
    # and the space is rewritten at the current version on the next flush
    stale.put(skey, sig, cost)
    stale.flush()
    assert json.loads(f.read_text())["version"] == STORE_VERSION

    # truncated/garbled file: ignored, store starts fresh
    f.write_text("{\"version\": this is not json")
    broken = ResultStore(tmp_path)
    assert broken.get(skey, sig) is None
    assert broken.corrupt == 1
    broken.put(skey, sig, cost)
    broken.flush()
    again = ResultStore(tmp_path)
    assert _costs_equal(again.get(skey, sig), cost)


def _sig_pool(problem, arch, n, seed=0):
    ctx = get_context(problem, arch)
    space = MapSpace(problem, arch)
    rng = random.Random(seed)
    sigs, seen = [], set()
    while len(sigs) < n:
        s = space.random_genome(rng).signature(ctx.dims)
        if s not in seen:
            seen.add(s)
            sigs.append(s)
    return sigs


def test_store_eviction_cap_and_lru_order(tmp_path):
    """The per-space cap is respected in both tiers, eviction is LRU
    (a ``get`` refreshes recency), and flush compacts the disk tier."""
    arch = edge_accelerator()
    cm = TimeloopLikeModel()
    skey = space_key(cm, GEMM, arch)
    sigs = _sig_pool(GEMM, arch, 8)
    costs = {s: cm.evaluate_signature(GEMM, arch, s) for s in sigs}

    store = ResultStore(tmp_path / "s", max_entries_per_space=4)
    for s in sigs[:4]:
        store.put(skey, s, costs[s])
    # touch the OLDEST entry so it becomes most recent
    assert store.get(skey, sigs[0]) is not None
    # two more puts evict the two least-recently-used (sigs[1], sigs[2])
    store.put(skey, sigs[4], costs[sigs[4]])
    store.put(skey, sigs[5], costs[sigs[5]])
    assert store.evicted == 2
    assert store.get(skey, sigs[1]) is None
    assert store.get(skey, sigs[2]) is None
    assert store.get(skey, sigs[0]) is not None  # survived: recently used
    assert store.flush() == 4  # disk tier holds exactly the cap

    fresh = ResultStore(tmp_path / "s", max_entries_per_space=4)
    kept = [s for s in sigs if fresh.get(skey, s) is not None]
    assert len(kept) == 4
    assert sigs[0] in kept and sigs[4] in kept and sigs[5] in kept

    # an uncapped reader sees the same 4 surviving entries
    uncapped = ResultStore(tmp_path / "s")
    assert sum(uncapped.get(skey, s) is not None for s in sigs) == 4


def test_store_concurrent_flush_union_of_survivors(tmp_path):
    """Two writers sharing a directory: flush unions the disk tier with
    the in-memory view before compacting, so below the cap NOTHING from
    either writer is lost, and above it exactly ``cap`` entries survive
    with the other writer's prior entries ranked least recent."""
    arch = edge_accelerator()
    cm = TimeloopLikeModel()
    skey = space_key(cm, GEMM, arch)
    sigs = _sig_pool(GEMM, arch, 10)
    costs = {s: cm.evaluate_signature(GEMM, arch, s) for s in sigs}

    # both writers opened before either flushes (lazy loads see no file)
    a = ResultStore(tmp_path / "s", max_entries_per_space=8)
    b = ResultStore(tmp_path / "s", max_entries_per_space=8)
    a.get(skey, sigs[0])  # force lazy load of the (absent) disk tier
    b.get(skey, sigs[0])
    for s in sigs[:4]:
        a.put(skey, s, costs[s])
    for s in sigs[4:8]:
        b.put(skey, s, costs[s])
    a.flush()
    b.flush()  # must union a's flushed entries, not clobber them
    merged = ResultStore(tmp_path / "s")
    assert sum(merged.get(skey, s) is not None for s in sigs[:8]) == 8

    # a third writer pushes the union past the cap: the oldest (on-disk,
    # i.e. other writers') entries are compacted away, newest survive
    c = ResultStore(tmp_path / "s", max_entries_per_space=8)
    for s in sigs[8:]:
        c.put(skey, s, costs[s])
    c.flush()
    final = ResultStore(tmp_path / "s")
    survivors = [s for s in sigs if final.get(skey, s) is not None]
    assert len(survivors) == 8
    assert sigs[8] in survivors and sigs[9] in survivors


def test_store_multi_space_flush_concurrent_writers_union(tmp_path):
    """Multi-space flush batching: one flush call writes ALL dirty spaces
    in a single atomic pass (one lock acquisition), and concurrent
    writers whose dirty sets cover DIFFERENT spaces -- plus one shared
    space -- still union losslessly on disk."""
    import threading

    arch_e, arch_c = edge_accelerator(), cloud_accelerator()
    cm = TimeloopLikeModel()
    key_e = space_key(cm, GEMM, arch_e)
    key_c = space_key(cm, GEMM, arch_c)
    key_conv = space_key(cm, CONV, arch_e)
    sigs_e = _sig_pool(GEMM, arch_e, 6)
    sigs_c = _sig_pool(GEMM, arch_c, 6)
    sigs_v = _sig_pool(CONV, arch_e, 6)
    ce = {s: cm.evaluate_signature(GEMM, arch_e, s) for s in sigs_e}
    cc = {s: cm.evaluate_signature(GEMM, arch_c, s) for s in sigs_c}
    cv = {s: cm.evaluate_signature(CONV, arch_e, s) for s in sigs_v}

    a = ResultStore(tmp_path / "s")
    b = ResultStore(tmp_path / "s")
    # writer a: edge space + half the shared conv space
    for s in sigs_e:
        a.put(key_e, s, ce[s])
    for s in sigs_v[:3]:
        a.put(key_conv, s, cv[s])
    # writer b: cloud space + the other half of the shared conv space
    for s in sigs_c:
        b.put(key_c, s, cc[s])
    for s in sigs_v[3:]:
        b.put(key_conv, s, cv[s])
    assert len(a._dirty) == 2 and len(b._dirty) == 2

    errs = []

    def flush(st):
        try:
            st.flush()
        except Exception as e:  # pragma: no cover - diagnostic
            errs.append(e)

    ta, tb = threading.Thread(target=flush, args=(a,)), threading.Thread(
        target=flush, args=(b,)
    )
    ta.start(), tb.start()
    ta.join(), tb.join()
    assert not errs
    assert not a._dirty and not b._dirty

    merged = ResultStore(tmp_path / "s")
    assert all(merged.get(key_e, s) is not None for s in sigs_e)
    assert all(merged.get(key_c, s) is not None for s in sigs_c)
    assert all(merged.get(key_conv, s) is not None for s in sigs_v)
    # flush with no dirty spaces is a cheap no-op
    assert a.flush() == 0


def test_store_space_key_canonicalizes_numpy_scalars():
    """numpy scalar arch attrs must not fork the space key: repr() of
    np.float64(x) differs from repr(x) on numpy>=2, which silently
    orphaned disk entries across writers."""
    base = edge_accelerator()
    k_base = space_key(TimeloopLikeModel(), GEMM, base)

    npy = edge_accelerator()
    npy.attrs["word_bytes"] = np.int64(npy.attrs["word_bytes"])
    npy.attrs["extra_bw"] = np.float64(2.0)
    plain = edge_accelerator()
    plain.attrs["extra_bw"] = 2.0
    assert space_key(TimeloopLikeModel(), GEMM, npy) == space_key(
        TimeloopLikeModel(), GEMM, plain
    )

    # numpy scalar fill_bandwidth (incl. the inf encoding) is canonical too
    npy_bw = edge_accelerator()
    npy_bw.clusters = [
        dataclasses.replace(c, fill_bandwidth=np.float64(c.fill_bandwidth))
        for c in npy_bw.clusters
    ]
    assert space_key(TimeloopLikeModel(), GEMM, npy_bw) == k_base

    # different VALUES still separate
    other = edge_accelerator()
    other.attrs["extra_bw"] = 3.0
    assert space_key(TimeloopLikeModel(), GEMM, other) != space_key(
        TimeloopLikeModel(), GEMM, plain
    )


def test_store_space_key_separates_configurations():
    arch = edge_accelerator()
    k1 = space_key(TimeloopLikeModel(), GEMM, arch)
    assert k1 == space_key(TimeloopLikeModel(), GEMM, arch)  # deterministic
    assert k1 != space_key(MaestroLikeModel(), GEMM, arch)
    assert k1 != space_key(TimeloopLikeModel(), CONV, arch)
    assert k1 != space_key(TimeloopLikeModel(), GEMM, cloud_accelerator())
    assert k1 != space_key(TimeloopLikeModel("mac3"), GEMM, arch)  # model config
    # problem NAME is excluded: identical shapes share the space
    renamed = Problem.gemm(64, 32, 16, name="other_layer", word_bytes=1)
    assert k1 == space_key(TimeloopLikeModel(), renamed, arch)


def test_store_warm_search_identical_outputs(tmp_path):
    """A second (warm) run with the on-disk store reports nonzero store
    hits and byte-identical outputs, across mappers and models."""
    arch = cloud_accelerator()
    for mapper, cm, kw in (
        ("random", "timeloop", {"samples": 300}),
        ("heuristic", "maestro", {}),
    ):
        base = union_opt(GEMM, arch, mapper=mapper, cost_model=cm, **kw)
        cold_store = ResultStore(tmp_path / "s")
        cold = union_opt(GEMM, arch, mapper=mapper, cost_model=cm,
                         result_store=cold_store, **kw)
        cold_store.flush()
        warm_store = ResultStore(tmp_path / "s")
        warm = union_opt(GEMM, arch, mapper=mapper, cost_model=cm,
                         result_store=warm_store, **kw)
        assert warm.search.store_hits > 0, (mapper, cm)
        assert warm.search.analyzed == 0, (mapper, cm)  # nothing re-scored
        for sol in (cold, warm):
            assert sol.cost.edp == base.cost.edp, (mapper, cm)
            assert sol.mapping.to_dict() == base.mapping.to_dict(), (mapper, cm)
        # the submitted-candidate total is warm/cold INVARIANT even though
        # the evaluated/pruned split shifts (store hits bypass admission)
        assert (
            base.search.considered
            == cold.search.considered
            == warm.search.considered
        ), (mapper, cm)
        assert warm.search.considered > 0


def test_search_counters_include_phases_and_store():
    sol = union_opt(GEMM, cloud_accelerator(), mapper="random",
                    cost_model="timeloop", samples=400)
    d = sol.search.stats_dict()
    for key in ("store_hits", "admit_s", "score_s", "considered",
                "fused_dispatches"):
        assert key in d
    assert d["store_hits"] == 0  # no store attached
    assert d["considered"] >= d["candidates"] > 0
    assert d["admit_s"] >= 0.0 and d["score_s"] > 0.0


# --------------------------------------------------------------------- #
# Read-refresh mode + space metadata (nearest-neighbor warm start)
# --------------------------------------------------------------------- #
def test_store_refresh_reloads_foreign_flush(tmp_path):
    """A refresh-mode store sees another process's flush on a get-miss
    (mtime probe + reload + ``reloads`` counter); a plain store does
    not; a store's OWN flush never triggers a self-reload."""
    arch = edge_accelerator()
    cm = TimeloopLikeModel()
    ctx = get_context(GEMM, arch)
    space = MapSpace(GEMM, arch)
    rng = random.Random(0)
    sigs = [space.random_genome(rng).signature(ctx.dims) for _ in range(3)]
    skey = space_key(cm, GEMM, arch)
    costs = {s: cm.evaluate_signature(GEMM, arch, s) for s in sigs}

    reader = ResultStore(tmp_path / "s", refresh=True)
    plain = ResultStore(tmp_path / "s")
    assert reader.get(skey, sigs[0]) is None  # both load the empty tier
    assert plain.get(skey, sigs[0]) is None

    writer = ResultStore(tmp_path / "s")
    writer.put(skey, sigs[0], costs[sigs[0]])
    writer.flush()

    got = reader.get(skey, sigs[0])
    assert got is not None and _costs_equal(got, costs[sigs[0]])
    assert reader.reloads == 1
    assert plain.get(skey, sigs[0]) is None  # no refresh, no reload
    assert plain.reloads == 0

    # a self-flush records its own mtime: no spurious self-reload
    reader.put(skey, sigs[1], costs[sigs[1]])
    reader.flush()
    assert reader.get(skey, sigs[1]) is not None
    assert reader.reloads == 1
    assert reader.stats_dict()["reloads"] == 1


def test_store_space_meta_roundtrip_and_nearest(tmp_path):
    """register_space_meta persists through flush; nearest_space picks
    the content-closest space under the SAME model + arch only, honors
    ``exclude``, and best_in_space returns the space's stored minimum."""
    arch = edge_accelerator()
    cm = TimeloopLikeModel()
    probs = {
        "close": Problem.gemm(64, 64, 48, name="near-a"),
        "far": Problem.gemm(1024, 1024, 1024, name="near-b"),
    }
    query = Problem.gemm(64, 64, 64, name="near-q")
    store = ResultStore(tmp_path / "s")
    keys = {}
    for tag, p in probs.items():
        sp = MapSpace(p, arch)
        ctx = get_context(p, arch)
        skey = space_key(cm, p, arch)
        keys[tag] = skey
        store.register_space_meta(skey, cm, p, arch)
        rng = random.Random(1)
        for _ in range(4):
            sig = sp.random_genome(rng).signature(ctx.dims)
            store.put(skey, sig, cm.evaluate_signature(p, arch, sig))
    store.flush()
    assert (tmp_path / "s" / "_meta.json").exists()

    # a FRESH store (new process) reads the persisted meta registry
    fresh = ResultStore(tmp_path / "s")
    got = fresh.nearest_space(cm, query, arch)
    assert got is not None
    skey, dist = got
    assert skey == keys["close"]
    assert dist >= 0.0
    # exclude the winner: the far space is next
    skey2, dist2 = fresh.nearest_space(cm, query, arch, exclude=keys["close"])
    assert skey2 == keys["far"] and dist2 > dist
    # registration is idempotent
    fresh.register_space_meta(keys["close"], cm, probs["close"], arch)
    assert fresh.space_meta(keys["close"])["macs"] == 64 * 64 * 48

    best = fresh.best_in_space(keys["close"], "edp")
    d = fresh._space(keys["close"])
    assert best == min(c.metric("edp") for c in d.values())
    assert fresh.best_in_space("no-such-space", "edp") is None


def test_store_nearest_space_filters_model_and_arch(tmp_path):
    """Costs from a different cost model or machine are not comparable:
    they must never be offered as a neighbor."""
    store = ResultStore(tmp_path / "s")
    tl, ms = TimeloopLikeModel(), MaestroLikeModel()
    edge, cloud = edge_accelerator(), cloud_accelerator()
    p = Problem.gemm(128, 128, 64, name="nn-f")
    store.register_space_meta(space_key(ms, p, edge), ms, p, edge)
    store.register_space_meta(space_key(tl, p, cloud), tl, p, cloud)
    assert store.nearest_space(tl, p, edge) is None
    store.register_space_meta(space_key(tl, p, edge), tl, p, edge)
    got = store.nearest_space(tl, Problem.gemm(128, 128, 96), edge)
    assert got is not None and got[0] == space_key(tl, p, edge)


def test_store_meta_corruption_tolerated(tmp_path):
    sdir = tmp_path / "s"
    sdir.mkdir()
    (sdir / "_meta.json").write_text("{definitely not json")
    store = ResultStore(sdir)
    p = Problem.gemm(32, 32, 32, name="nn-c")
    cm = TimeloopLikeModel()
    arch = edge_accelerator()
    assert store.nearest_space(cm, p, arch) is None
    assert store.corrupt == 1
    # registration + flush rewrites a clean registry
    store.register_space_meta(space_key(cm, p, arch), cm, p, arch)
    store.flush()
    fresh = ResultStore(sdir)
    assert fresh.nearest_space(cm, Problem.gemm(48, 32, 32), arch) is not None
