"""Per-architecture smoke tests: a REDUCED same-family config runs one
forward + one train step on CPU with correct shapes and no NaNs; decoder
archs also run a decode step whose logits match a fresh forward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, list_configs
from repro.launch import steps as steps_mod
from repro.models import decode_step, forward, init_cache, init_params, loss_fn
from repro.optim.optimizers import adamw

ARCHS = list(list_configs())
B, S = 2, 32


def make_batch(cfg, key):
    if cfg.frontend == "audio_stub":
        return {
            "frames": jax.random.normal(key, (B, S, cfg.d_frontend), jnp.bfloat16),
            "labels": jax.random.randint(key, (B, S), 0, cfg.vocab),
        }
    if cfg.frontend == "vision_stub":
        n_img = cfg.n_frontend_tokens
        return {
            "tokens": jax.random.randint(key, (B, S - n_img), 0, cfg.vocab),
            "patch_embeds": jax.random.normal(key, (B, n_img, cfg.d_frontend), jnp.bfloat16),
        }
    return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}


def test_all_ten_architectures_assigned():
    assert len(ARCHS) == 10


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch + "_smoke")
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    logits, aux = forward(cfg, params, batch, remat=False)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_decreases_loss_and_finite(arch):
    cfg = get_config(arch + "_smoke")
    opt = adamw(1e-3)
    step = jax.jit(steps_mod.make_train_step(cfg, opt, remat=True))
    state = steps_mod.make_init_state(cfg, opt)(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    losses = []
    for _ in range(3):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(l) for l in losses)
    assert losses[-1] < losses[0]  # same batch thrice must overfit
    assert int(state["opt"]["step"]) == 3


@pytest.mark.parametrize(
    "arch", [a for a in ARCHS if get_config(a).supports_decode and
             get_config(a).frontend == "none"]
)
def test_decode_matches_forward(arch):
    """Token-by-token decode with the cache == one full forward pass.
    Params cast to f32: the comparison isolates cache/step LOGIC from the
    ~1e-2 bf16 noise of chunked-vs-recurrent accumulation order."""
    import dataclasses

    cfg = get_config(arch + "_smoke")
    if cfg.n_routed_experts:
        # unbind capacity so the FULL forward drops nothing either (decode
        # is dropless by design; see models/moe.py)
        cfg = dataclasses.replace(cfg, capacity_factor=100.0)
    key = jax.random.PRNGKey(0)
    params = jax.tree.map(
        lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a,
        init_params(cfg, key),
    )
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 8), 0, cfg.vocab)
    full_logits, _ = forward(cfg, params, {"tokens": toks}, remat=False)
    cache = jax.tree.map(
        lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a,
        init_cache(cfg, B, 16),
    )
    dec = jax.jit(lambda p, c, t, pos: decode_step(cfg, p, c, t, pos))
    outs = []
    for t in range(8):
        lg, cache = dec(params, cache, toks[:, t : t + 1], jnp.int32(t))
        outs.append(lg)
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32),
        np.asarray(full_logits, np.float32),
        rtol=2e-3, atol=2e-3,
    )


@pytest.mark.parametrize("arch", ARCHS)
def test_microbatched_step_matches_full(arch):
    """Gradient accumulation is numerically the same optimizer step.

    Exception encoded here: MoE archs legitimately differ slightly -- the
    router's load-balancing aux statistics are computed per microbatch
    over fewer tokens, so accumulation changes the aux term (true of every
    MoE framework; see DESIGN.md).
    """
    cfg = get_config(arch + "_smoke")
    moe = cfg.n_routed_experts > 0
    opt = adamw(1e-3, grad_clip=None)
    s1 = jax.jit(steps_mod.make_train_step(cfg, opt, remat=False, microbatches=1))
    s2 = jax.jit(steps_mod.make_train_step(cfg, opt, remat=False, microbatches=2))
    state = steps_mod.make_init_state(cfg, opt)(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    st1, m1 = s1(state, batch)
    st2, m2 = s2(state, batch)
    np.testing.assert_allclose(
        float(m1["loss"]), float(m2["loss"]), rtol=5e-2 if moe else 1e-4
    )
    # compare f32 master weights (bf16 params quantize the tiny one-step
    # adam delta); near-zero grads can flip the sign-like m/sqrt(v) update,
    # so the bound is ~2 * lr
    ma1 = jax.tree.leaves(st1["opt"]["master"])[0]
    ma2 = jax.tree.leaves(st2["opt"]["master"])[0]
    np.testing.assert_allclose(np.asarray(ma1), np.asarray(ma2), atol=4e-3 if moe else 2.5e-3)


def test_encoder_only_has_no_decode():
    cfg = get_config("hubert-xlarge")
    assert not cfg.supports_decode


def test_subquadratic_flags():
    assert get_config("zamba2-2.7b").subquadratic
    assert get_config("xlstm-1.3b").subquadratic
    assert not get_config("codeqwen1.5-7b").subquadratic
