"""Data pipeline: determinism, resume semantics, file-backed source."""

import numpy as np

from repro.data import DataConfig, SyntheticLM, TokenFileDataset, make_pipeline


def test_synthetic_deterministic():
    a = SyntheticLM(vocab=100, seed=7).batch(3, 4, 16)["tokens"]
    b = SyntheticLM(vocab=100, seed=7).batch(3, 4, 16)["tokens"]
    np.testing.assert_array_equal(a, b)
    c = SyntheticLM(vocab=100, seed=8).batch(3, 4, 16)["tokens"]
    assert not np.array_equal(a, c)
    assert a.min() >= 0 and a.max() < 100


def test_synthetic_is_learnable_structure():
    """80% of transitions follow the fixed successor table."""
    src = SyntheticLM(vocab=50, seed=0)
    b = src.batch(0, 64, 128)["tokens"]
    follows = (src._succ[b[:, :-1]] == b[:, 1:]).mean()
    assert 0.7 < follows < 0.95


def test_pipeline_resume_replays_identically():
    src = SyntheticLM(vocab=100, seed=0)
    p1 = make_pipeline(src, 2, 8, start_step=0, data_cfg=DataConfig(prefetch=1))
    run1 = [np.asarray(next(p1)["tokens"]) for _ in range(5)]
    p2 = make_pipeline(src, 2, 8, start_step=3, data_cfg=DataConfig(prefetch=1))
    run2 = [np.asarray(next(p2)["tokens"]) for _ in range(2)]
    np.testing.assert_array_equal(run1[3], run2[0])
    np.testing.assert_array_equal(run1[4], run2[1])


def test_token_file_dataset(tmp_path):
    toks = np.arange(10000, dtype=np.int32) % 97
    f = tmp_path / "toks.bin"
    toks.tofile(f)
    ds = TokenFileDataset(f, vocab=97, seed=0)
    a = ds.batch(0, 4, 32)["tokens"]
    b = ds.batch(0, 4, 32)["tokens"]
    np.testing.assert_array_equal(a, b)
    assert a.shape == (4, 32)
    assert a.max() < 97
