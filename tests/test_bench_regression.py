"""mappers_bench regression-gate bootstrap semantics.

The smoke-mode evals/s gate must bootstrap cleanly on first runs: a
missing ``BENCH_mappers.json`` is recorded (warn-and-record, no gate), a
baseline lacking a row for a newly-benchmarked mapper/backend records
that row without touching existing rows, a genuine regression still
fails, and matrix mismatches skip the gate as before.
"""

import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.mappers_bench import check_regression  # noqa: E402


def _summary(rows: dict, smoke=True, backends=("numpy",)) -> dict:
    return {
        "problem": "BERT-2",
        "smoke": smoke,
        "engine_backends": list(backends),
        "evals_per_s": dict(rows),
        "cache_hit_rate": {k: 0.1 for k in rows},
        "pruned": {k: 5 for k in rows},
        "store_hits": {k: 0 for k in rows},
        "phase_s": {k: {"admit": 0.01, "score": 0.02} for k in rows},
        "speedup_vs_seed": {},
    }


def test_missing_baseline_bootstraps(tmp_path, capsys):
    path = tmp_path / "BENCH_mappers.json"
    summary = _summary({"timeloop/random": 10000})
    check_regression(summary, path, margin=0.5)  # must not raise
    out = capsys.readouterr().out
    assert "no baseline" in out and "recording" in out
    assert json.loads(path.read_text())["evals_per_s"] == {"timeloop/random": 10000}


def test_new_mapper_row_recorded_without_touching_existing(tmp_path, capsys):
    path = tmp_path / "BENCH_mappers.json"
    path.write_text(json.dumps(_summary({"timeloop/random": 10000})))
    summary = _summary({"timeloop/random": 11000, "timeloop/heuristic": 7000})
    check_regression(summary, path, margin=0.5)  # new row: warn, not fail
    out = capsys.readouterr().out
    assert "WARNING" in out and "timeloop/heuristic" in out
    base = json.loads(path.read_text())
    # the first-run row was recorded; the committed floor was NOT ratcheted
    assert base["evals_per_s"]["timeloop/heuristic"] == 7000
    assert base["evals_per_s"]["timeloop/random"] == 10000
    # a later regression on the recorded row now fails
    with pytest.raises(SystemExit):
        check_regression(
            _summary({"timeloop/random": 11000, "timeloop/heuristic": 1000}),
            path,
            margin=0.5,
        )


def test_regression_still_fails(tmp_path):
    path = tmp_path / "BENCH_mappers.json"
    path.write_text(json.dumps(_summary({"timeloop/random": 10000})))
    with pytest.raises(SystemExit):
        check_regression(_summary({"timeloop/random": 1000}), path, margin=0.5)


def test_regression_not_recorded_on_failure(tmp_path):
    """A run that both regresses an existing row and introduces a new one
    must fail WITHOUT recording the new row (a broken run is not a
    trustworthy baseline)."""
    path = tmp_path / "BENCH_mappers.json"
    path.write_text(json.dumps(_summary({"timeloop/random": 10000})))
    with pytest.raises(SystemExit):
        check_regression(
            _summary({"timeloop/random": 1000, "timeloop/heuristic": 7000}),
            path,
            margin=0.5,
        )
    assert "timeloop/heuristic" not in json.loads(path.read_text())["evals_per_s"]


def test_matrix_mismatch_skips_gate(tmp_path, capsys):
    path = tmp_path / "BENCH_mappers.json"
    path.write_text(json.dumps(_summary({"numpy/timeloop/random": 10000})))
    check_regression(
        _summary({"numpy/timeloop/random": 1}, smoke=False), path, margin=0.5
    )
    assert "matrix differs" in capsys.readouterr().out
    # and the baseline was left alone
    assert json.loads(path.read_text())["evals_per_s"] == {
        "numpy/timeloop/random": 10000
    }


def test_backend_rows_gate_independently(tmp_path, capsys):
    """Per-backend keys: a jax row never gates a numpy row; a first-run
    backend's rows bootstrap (warn-and-record) while existing backends
    keep their floors."""
    path = tmp_path / "BENCH_mappers.json"
    path.write_text(json.dumps(_summary({"numpy/timeloop/random": 10000})))
    summary = _summary(
        {"numpy/timeloop/random": 11000, "jax/timeloop/random": 7000},
        backends=("numpy", "jax"),
    )
    check_regression(summary, path, margin=0.5)  # new backend: warn, record
    out = capsys.readouterr().out
    assert "WARNING" in out and "jax/timeloop/random" in out
    base = json.loads(path.read_text())
    assert base["evals_per_s"]["jax/timeloop/random"] == 7000
    assert base["evals_per_s"]["numpy/timeloop/random"] == 10000
    # the recorded jax floor now gates jax runs
    import pytest as _pytest
    with _pytest.raises(SystemExit):
        check_regression(
            _summary(
                {"numpy/timeloop/random": 11000, "jax/timeloop/random": 1000},
                backends=("numpy", "jax"),
            ),
            path,
            margin=0.5,
        )
