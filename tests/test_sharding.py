"""Sharding rules: divisibility guards, family-specific layouts, and the
Union-mapping <-> PartitionSpec correspondence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import SHAPES, get_config
from repro.launch.specs import input_specs
from repro.sharding.hints import clear_hints, hints, shard_hint
from repro.sharding.specs import (
    ShardingRules,
    _maybe,
    _maybe_dp,
    batch_specs,
    cache_specs,
    dp_axes,
    param_specs,
)

SIZES = {"pod": 2, "data": 16, "model": 16}


class _FakeMesh:
    """Shape-only stand-in: spec builders only read axis_names/devices.shape."""

    def __init__(self, sizes):
        self.axis_names = tuple(sizes)
        import numpy as _np

        self.devices = _np.empty(tuple(sizes.values()), object)


MESH = _FakeMesh(SIZES)


def test_maybe_divisibility_guard():
    assert _maybe("model", 64, SIZES) == "model"
    assert _maybe("model", 56, SIZES) is None  # llava's 56 heads on 16-way
    assert _maybe("model", 4, SIZES) is None   # starcoder2's 4 kv heads
    assert _maybe(None, 64, SIZES) is None
    assert _maybe_dp(("pod", "data"), 64, SIZES) == ("pod", "data")
    assert _maybe_dp(("pod", "data"), 1, SIZES) is None  # batch-1 long ctx


def test_param_specs_dense():
    cfg = get_config("qwen3-0.6b")
    ps = jax.eval_shape(
        lambda: {"units": {"b0": {"attn": {
            "wq": {"w": jnp.zeros((8, cfg.d_model, cfg.n_heads * cfg.head_dim), jnp.bfloat16)},
            "wo": {"w": jnp.zeros((8, cfg.n_heads * cfg.head_dim, cfg.d_model), jnp.bfloat16)},
        }}},
            "embed": jnp.zeros((cfg.vocab, cfg.d_model), jnp.bfloat16)}
    )
    specs = param_specs(ps, cfg, MESH, ShardingRules())
    wq = specs["units"]["b0"]["attn"]["wq"]["w"]
    assert wq[0] is None            # stacked-unit axis never sharded
    assert wq[-1] == "model"        # column-parallel
    assert wq[1] == "data"          # FSDP on the other big dim
    wo = specs["units"]["b0"]["attn"]["wo"]["w"]
    assert wo[1] == "model"         # row-parallel
    emb = specs["embed"]
    assert emb[0] == "model"        # vocab-sharded embedding


def test_param_specs_inference_disables_fsdp():
    cfg = get_config("qwen3-0.6b")
    ps = jax.eval_shape(lambda: {"attn": {"wq": {"w": jnp.zeros((1024, 2048), jnp.bfloat16)}}})
    sp = param_specs(ps, cfg, MESH, ShardingRules(), for_training=False)
    assert sp["attn"]["wq"]["w"][0] is None


def test_cache_specs_head_vs_sequence_fallback():
    rules = ShardingRules()
    # qwen3: kv=8 NOT divisible by 16 -> sequence-sharded over model
    cfg = get_config("qwen3-0.6b")
    cs = jax.eval_shape(lambda: {"units": {"b0": {
        "k": jnp.zeros((8, 128, 32768, cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16)}}})
    sp = cache_specs(cs, cfg, MESH, rules)["units"]["b0"]["k"]
    assert sp[3] is None and sp[2] == "model"
    # codeqwen kv=32 divisible -> head-sharded
    cfg2 = get_config("codeqwen1.5-7b")
    cs2 = jax.eval_shape(lambda: {"units": {"b0": {
        "k": jnp.zeros((8, 128, 32768, cfg2.n_kv_heads, cfg2.head_dim), jnp.bfloat16)}}})
    sp2 = cache_specs(cs2, cfg2, MESH, rules)["units"]["b0"]["k"]
    assert sp2[3] == "model"
    assert sp2[1] == ("pod", "data")  # batch 128 shardable


def test_cache_specs_batch1_seq_over_dp():
    """long_500k: batch axis unshardable -> cache sequence takes dp axes."""
    cfg = get_config("zamba2-2.7b")
    cs = jax.eval_shape(lambda: {"units": {"b0": {
        "k": jnp.zeros((7, 1, 524288, 32, 80), jnp.bfloat16)}}})
    sp = cache_specs(cs, cfg, MESH, ShardingRules())["units"]["b0"]["k"]
    assert sp[1] is None
    assert sp[2] == ("pod", "data")


def test_input_specs_struct_only():
    """input_specs produces ShapeDtypeStructs (no allocation) for all kinds."""
    for arch, shape in [("qwen3-0.6b", "train_4k"), ("qwen3-0.6b", "prefill_32k"),
                        ("qwen3-0.6b", "decode_32k"), ("hubert-xlarge", "train_4k"),
                        ("llava-next-34b", "prefill_32k")]:
        spec = input_specs(arch, shape)
        for leaf in jax.tree.leaves(spec):
            assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_shard_hint_noop_outside_context():
    clear_hints()
    x = jnp.zeros((4, 4))
    assert shard_hint(x, "dp", "tp") is x


def test_shard_hint_respects_divisibility():
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    with hints(dp=("data",), tp="model", sizes={"data": 1, "model": 1}):
        with mesh:
            x = jnp.zeros((4, 6))
            y = shard_hint(x, "dp", "tp")
            assert y.shape == x.shape  # applies cleanly on a 1x1 mesh


def test_dp_axes_rules():
    r = ShardingRules()
    assert dp_axes(MESH, r) == ("pod", "data")
    r2 = ShardingRules(dp_over_pod=False)
    assert dp_axes(MESH, r2) == ("data",)
