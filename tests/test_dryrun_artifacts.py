"""Validate the committed multi-pod dry-run artifacts: the deliverable (e)
evidence. Every runnable (arch x shape) cell must have compiled on BOTH
meshes, fit HBM, and expose the roofline inputs."""

import json
from pathlib import Path

import pytest

from repro.configs.base import SHAPES, get_config, runnable_cells

ART_DIR = Path(__file__).resolve().parent.parent / "experiments" / "dryrun"
CELLS = runnable_cells()
MESHES = ["16x16", "2x16x16"]


def load(arch, shape, mesh):
    p = ART_DIR / f"{arch}__{shape}__{mesh}.json"
    if not p.exists():
        pytest.skip(f"dry-run artifact missing (run repro.launch.dryrun): {p.name}")
    return json.loads(p.read_text())


def test_cell_count_matches_skip_rules():
    # 40 nominal cells - 9 mandated skips (4 long_500k quadratic-only archs
    # are actually 8 skips... computed from the rules, not hardcoded)
    n_archs = 10
    nominal = n_archs * 4
    assert len(CELLS) == 31
    skips = nominal - len(CELLS)
    assert skips == 9


@pytest.mark.parametrize("mesh", MESHES)
@pytest.mark.parametrize("arch,shape", CELLS)
def test_artifact_complete_and_fits(arch, shape, mesh):
    art = load(arch, shape, mesh)
    assert art["chips"] == (512 if mesh == "2x16x16" else 256)
    assert art["flops_per_device"] > 0
    assert art["bytes_per_device"] > 0
    assert art["model_flops"] > 0
    # the TPU-dtype-corrected residency estimate must fit 16 GB HBM
    assert art["memory_tpu_analytic"]["fits_hbm"], (
        f"{arch}/{shape}/{mesh}: {art['memory_tpu_analytic']['total_bytes']/2**30:.1f} GiB"
    )


@pytest.mark.parametrize("arch,shape", [c for c in CELLS if SHAPES[c[1]].kind == "train"])
def test_train_cells_have_collectives(arch, shape):
    """A sharded train step without collectives means the sharding silently
    replicated -- every train cell must all-reduce gradients."""
    art = load(arch, shape, "2x16x16")
    assert art["collectives"]["all-reduce_count"] > 0
    assert art["collective_bytes_per_device"] > 0


@pytest.mark.parametrize("arch,shape", CELLS)
def test_multipod_vs_singlepod_flops_scale(arch, shape):
    """Per-device FLOPs must drop going 256 -> 512 chips (the pod axis
    actually shards work; if it replicated, FLOPs/device would be equal).
    Uses the structure-corrected numbers: they are microbatch-invariant
    (the 110B train cell auto-picks mb=2 on 16x16 but mb=1 on 2x16x16,
    and the raw cost_analysis counts the grad-accum scan body once)."""
    a1 = load(arch, shape, "16x16")
    a2 = load(arch, shape, "2x16x16")
    if SHAPES[shape].global_batch == 1:
        pytest.skip("batch-1 cell: pod axis shards memory, not batch FLOPs")
    if get_config(arch).n_routed_experts and SHAPES[shape].kind == "decode":
        # GSPMD replicates MoE expert compute (the documented SPerf
        # pathology): the batch-sharded part scales, the replicated expert
        # part dominates decode. The EP shard_map path fixes it for train.
        pytest.skip("MoE decode: expert compute replicated under GSPMD")
    f1 = a1.get("corrected", a1)["flops_per_device"]
    f2 = a2.get("corrected", a2)["flops_per_device"]
    assert f2 < f1 * 0.75


def test_useful_flops_fraction_sane():
    """MODEL_FLOPS / structure-corrected HLO FLOPs for train cells: remat
    + attention/router overhead bound the ratio into (0.05, 1.05]. Uses
    the corrected costs (cost_analysis counts scan bodies once; see
    dryrun.corrected_costs)."""
    for arch, shape in CELLS:
        if SHAPES[shape].kind != "train":
            continue
        art = load(arch, shape, "16x16")
        if "corrected" not in art:
            pytest.skip("artifact predates correction pass")
        if get_config(arch).n_routed_experts:
            # the GSPMD MoE baseline replicates expert compute (useful
            # FLOPs 0.01-0.02 -- the documented SPerf pathology). The EP
            # variant must meet the bound instead, when present.
            ep = ART_DIR / f"{arch}__{shape}__16x16__ep.json"
            if ep.exists():
                a = json.loads(ep.read_text())
                r = a["model_flops"] / (a["corrected"]["flops_per_device"] * a["chips"])
                assert 0.05 < r <= 1.05, (arch, shape, "ep", r)
            continue
        total_hlo = art["corrected"]["flops_per_device"] * art["chips"]
        ratio = art["model_flops"] / total_hlo
        assert 0.05 < ratio <= 1.05, (arch, shape, ratio)
