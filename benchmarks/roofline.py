"""Roofline table: read every dry-run artifact and emit the SRoofline rows
(three terms, dominant bound, useful-FLOPs ratio, roofline fraction).

Writes experiments/roofline.md (the table embedded in EXPERIMENTS.md) and
experiments/benchmarks/roofline.json.
"""

from __future__ import annotations

import json
from pathlib import Path

from repro.configs.base import SHAPES
from repro.core.cost.roofline import RooflineReport

ART = Path("experiments/dryrun")
OUT = Path("experiments/benchmarks")


def suggestion(rep: RooflineReport, art: dict) -> str:
    """One sentence: what would move the dominant term down."""
    kind = SHAPES[art["shape"]].kind
    if rep.bound == "collective":
        return ("overlap/shrink collectives: reduce-scatter instead of "
                "all-reduce + int8 cross-pod compression")
    if rep.bound == "memory":
        if kind == "decode":
            return ("decode is weight/KV-bandwidth bound: quantize KV cache "
                    "or raise batch to amortize weight reads")
        return "raise arithmetic intensity: larger per-chip tiles, less remat"
    return "compute-bound: reduce remat recompute or shard the unsharded axis"


def run(mesh: str = "16x16") -> dict:
    rows = []
    for p in sorted(ART.glob(f"*__{mesh}.json")):
        art = json.loads(p.read_text())
        if art.get("tag"):
            continue  # perf-iteration variants are reported in SPerf
        rep = RooflineReport.from_artifact(art["cell"], art)
        r = rep.row()
        r["arch"], r["shape"] = art["arch"], art["shape"]
        r["fits_hbm"] = art.get("memory_tpu_analytic", art["memory"])["fits_hbm"]
        r["what_to_do"] = suggestion(rep, art)
        rows.append(r)

    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    lines = [
        f"| arch | shape | compute (s) | memory (s) | collective (s) | bound "
        f"| useful FLOPs | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | {r['bound']} | "
            f"{r['useful_flops_frac']:.2f} | {r['roofline_frac']:.2%} |"
        )
    table = "\n".join(lines)
    OUT.mkdir(parents=True, exist_ok=True)
    Path("experiments/roofline.md").write_text(table + "\n")
    (OUT / "roofline.json").write_text(json.dumps(rows, indent=1))

    bounds = {}
    for r in rows:
        bounds[r["bound"]] = bounds.get(r["bound"], 0) + 1
    print(f"[roofline] {len(rows)} cells on {mesh}: bound distribution {bounds}")
    worst = sorted((r for r in rows if r["roofline_frac"] > 0),
                   key=lambda r: r["roofline_frac"])[:5]
    for r in worst:
        print(f"[roofline]   worst: {r['arch']}/{r['shape']} "
              f"frac={r['roofline_frac']:.2%} bound={r['bound']}")
    return {"rows": rows, "bounds": bounds}


if __name__ == "__main__":
    run()
