"""Kill-and-resume smoke drill: SIGKILL a sweep mid-flight, resume it,
and assert the resumed figure JSON is BYTE-IDENTICAL to an uninterrupted
run's.

Three child runs of the same fig10-style multi-group sweep (this script
re-execs itself with ``--emit``):

  1. reference  -- uninterrupted, ``UNION_DETERMINISTIC_STATS=1``.
  2. killed     -- same sweep with a journal and
                   ``UNION_FAULT_SPEC=kill-after:N``: the executor
                   SIGKILLs its own process after the Nth completed
                   group's store flush but BEFORE its journal record --
                   the worst crash ordering, exactly the window the
                   journal's atomic-replace discipline protects.
  3. resumed    -- same journal with ``--resume``: journaled groups are
                   replayed from their records, the rest re-searched.

The parent asserts the killed run actually died by SIGKILL, the resumed
run replayed at least one group, and ``cmp``-style byte equality of the
reference and resumed JSONs. Deterministic stats mode strips the
warm/cold-variant counters (timings, store hit counts) from the emitted
JSON so the comparison is exact -- see ``docs/sweep_service.md``.

Usage:
    python benchmarks/resume_smoke.py [--kill-after N] [--keep]
"""

from __future__ import annotations

import argparse
import json
import os
import re
import shutil
import signal
import subprocess
import sys
import tempfile
from pathlib import Path

from benchmarks.workloads import dnn_layers
from repro.core.architecture import edge_accelerator
from repro.core.optimizer import SweepTask, union_opt_sweep

_NAMES = ["DLRM-1", "BERT-1", "DLRM-2", "BERT-2"]


def build_tasks() -> list:
    layers = dnn_layers()
    tasks = []
    for wname in _NAMES:
        problem = layers[wname]
        arch = edge_accelerator(aspect=(16, 16))
        tasks.append(SweepTask(problem, arch, mapper="heuristic",
                               cost_model="timeloop", metric="edp",
                               tag=(wname, "heuristic")))
        tasks.append(SweepTask(problem, arch, mapper="random",
                               cost_model="timeloop", metric="edp",
                               mapper_kw={"samples": 2000},
                               tag=(wname, "random")))
    return tasks


def emit(out_path: str, journal: str | None, resume: bool) -> None:
    """Child mode: run the sweep and write the figure-style JSON."""
    tasks = build_tasks()
    sweep = union_opt_sweep(tasks, journal=journal, resume=resume)
    result = {
        "figure": "resume_smoke",
        "rows": {
            "/".join(t.tag): {
                "edp": s.cost.edp,
                "util": s.cost.utilization,
                "mapping": s.mapping.to_dict(),
                "search": s.search.stats_dict(),
            }
            for t, s in zip(tasks, sweep)
        },
        "sweep": sweep.stats,
    }
    Path(out_path).write_text(json.dumps(result, indent=1))


def _child(extra: list, env_extra: dict, workdir: str):
    env = dict(os.environ)
    env["UNION_DETERMINISTIC_STATS"] = "1"
    env.update(env_extra)
    root = Path(__file__).resolve().parent.parent
    env["PYTHONPATH"] = os.pathsep.join(
        [str(root / "src"), str(root)]
        + [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p]
    )
    return subprocess.run(
        [sys.executable, str(Path(__file__).resolve())] + extra,
        env=env, cwd=workdir, capture_output=True, text=True, timeout=600,
    )


def run(kill_after: int = 2, keep: bool = False) -> None:
    work = tempfile.mkdtemp(prefix="union_resume_smoke_")
    try:
        ref, out = f"{work}/ref.json", f"{work}/resumed.json"
        journal = f"{work}/sweep_journal.json"

        r = _child(["--emit", ref], {}, work)
        if r.returncode != 0:
            raise SystemExit(
                f"[resume_smoke] reference run failed:\n{r.stderr[-2000:]}")
        print("[resume_smoke] reference run OK")

        r = _child(["--emit", f"{work}/never.json", "--journal", journal],
                   {"UNION_FAULT_SPEC": f"kill-after:{kill_after}"}, work)
        if r.returncode != -signal.SIGKILL:
            raise SystemExit(
                f"[resume_smoke] expected the child to die by SIGKILL "
                f"(rc {-signal.SIGKILL}), got rc {r.returncode}:\n"
                f"{r.stderr[-2000:]}")
        if not Path(journal).exists():
            raise SystemExit("[resume_smoke] killed run left no journal")
        print(f"[resume_smoke] child SIGKILLed after {kill_after} "
              f"completed group(s) ({kill_after - 1} journaled); "
              f"journal survived")

        r = _child(["--emit", out, "--journal", journal, "--resume"], {}, work)
        if r.returncode != 0:
            raise SystemExit(
                f"[resume_smoke] resumed run failed:\n{r.stderr[-2000:]}")
        m = re.search(r"replaying (\d+)/(\d+)", r.stdout + r.stderr)
        replayed = int(m.group(1)) if m else 0
        if replayed < 1:
            raise SystemExit(
                "[resume_smoke] resumed run replayed no groups -- the "
                f"journal did not take:\n{(r.stdout + r.stderr)[-2000:]}")

        ref_bytes = Path(ref).read_bytes()
        out_bytes = Path(out).read_bytes()
        if ref_bytes != out_bytes:
            raise SystemExit(
                "[resume_smoke] BYTE MISMATCH between the uninterrupted "
                f"and resumed figure JSONs ({ref} vs {out}); kept at {work}")
        print(f"[resume_smoke] OK: resumed run replayed {replayed} group(s) "
              f"and its figure JSON is byte-identical to the uninterrupted "
              f"run ({len(ref_bytes)} bytes)")
    finally:
        if keep:
            print(f"[resume_smoke] artifacts kept at {work}")
        else:
            shutil.rmtree(work, ignore_errors=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--emit", default=None, metavar="OUT.json",
                    help="(child mode) run the sweep and write the figure "
                         "JSON instead of orchestrating the drill")
    ap.add_argument("--journal", default=None, metavar="FILE")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--kill-after", type=int, default=2, metavar="N",
                    help="SIGKILL the child after N journaled groups")
    ap.add_argument("--keep", action="store_true",
                    help="keep the work dir (journals + JSONs) for debugging")
    args = ap.parse_args()
    if args.emit:
        emit(args.emit, args.journal, args.resume)
    else:
        run(kill_after=args.kill_after, keep=args.keep)
