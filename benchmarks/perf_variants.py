"""SPerf variant table: collect tagged dry-run artifacts (baseline vs
rules variants) and print/emit the hypothesis-grid comparison."""

from __future__ import annotations

import json
from collections import defaultdict
from pathlib import Path

ART = Path("experiments/dryrun")
PEAK, HBM_BW, LINK_BW = 197e12, 819e9, 50e9


def terms(art: dict):
    c = art.get("corrected", art)
    comp = c["flops_per_device"] / PEAK
    mem = c["bytes_per_device"] / HBM_BW
    coll = c["collective_bytes_per_device"] / LINK_BW
    step = max(comp, mem, coll)
    rf = art["model_flops"] / (step * art["chips"] * PEAK) if step else 0.0
    return comp, mem, coll, step, rf


def run() -> dict:
    groups = defaultdict(dict)
    for p in sorted(ART.glob("*.json")):
        art = json.loads(p.read_text())
        base = f"{art['arch']}__{art['shape']}__{art['mesh']}"
        groups[base][art.get("tag") or "baseline"] = art

    rows = []
    for cell, variants in sorted(groups.items()):
        if len(variants) < 2:
            continue
        print(f"\n[perf] {cell}")
        base_step = None
        for tag in sorted(variants, key=lambda t: (t != "baseline", t)):
            comp, mem, coll, step, rf = terms(variants[tag])
            if tag == "baseline":
                base_step = step
            speed = f" ({base_step/step:5.2f}x)" if (base_step and tag != "baseline") else ""
            print(f"    {tag:16s} compute={comp:8.2f}s memory={mem:8.2f}s "
                  f"collective={coll:8.2f}s step={step:8.2f}s "
                  f"roofline={rf:6.2%}{speed}")
            rows.append({"cell": cell, "variant": tag, "compute_s": comp,
                         "memory_s": mem, "collective_s": coll,
                         "step_s": step, "roofline_frac": rf})
    out = Path("experiments/benchmarks")
    out.mkdir(parents=True, exist_ok=True)
    (out / "perf_variants.json").write_text(json.dumps(rows, indent=1))
    return {"rows": rows}


if __name__ == "__main__":
    run()
