"""Fig. 8 + Fig. 9: TC algorithm exploration -- native vs TTGT on the
cloud accelerator (32x64), Timeloop cost model, heuristic+random mappers.

Two map-space modes per problem:

  * paper mode  -- ``max_concurrent_spatial=1``: one dim per cluster level,
    i.e. the memory-target loop-centric space of Timeloop/Interstellar the
    paper's native-TC numbers come from. Reproduces the claim: at TDS=16
    every contraction is better through TTGT (native under-utilizes: a
    16-sized dim cannot fill a 32- or 64-wide axis).
  * union mode  -- the full cluster-target space (several dims distributed
    CONCURRENTLY per level, paper Sec. IV-D). Beyond-paper result: native
    TC regains full utilization and TTGT's advantage mostly disappears --
    Union's own mapping abstraction removes the inefficiency that
    motivated the TTGT rewrite at small TDS.

The TTGT side is costed end to end: the GEMM's EDP is combined with the
explicit transposes' DRAM traffic (``repro.core.ir.ttgt.transpose_cost``);
``--no-transpose-cost`` reproduces the historical GEMM-only numbers.

Also prints the found Union mappings for intensli2 (Fig. 9).
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from benchmarks.sweep_cli import add_sweep_args, deterministic_stats, sweep_kwargs
from benchmarks.workloads import tc_problems
from repro.core.architecture import cloud_accelerator
from repro.core.constraints import Constraints
from repro.core.cost import ResultStore
from repro.core.ir.ttgt import best_ttgt_plan, transpose_cost
from repro.core.optimizer import SweepTask, union_opt_sweep

OUT = Path("experiments/benchmarks")
PAPER_SPACE = Constraints(name="memory_target_like", max_concurrent_spatial=1)

# paper Sec. V-A: every (problem, space-mode) point is searched by a
# heuristic AND a random mapper, best of both reported
_MAPPERS = ("heuristic", "random")


def ttgt_total_edp(cost, plan, arch, include_transpose: bool = True,
                   word_bytes: int = 1, tcost=None) -> float:
    """EDP of the full TTGT pipeline: the GEMM's cost plus the explicit
    transposes' DRAM traffic (cycles and energy at the outermost level,
    see ``repro.core.ir.ttgt.transpose_cost``). ``include_transpose=False``
    reproduces the historical GEMM-only (undercosted) numbers. ``tcost``
    takes an already-computed ``(cycles, energy_pj)`` pair so callers that
    also report the pair charge exactly what they report."""
    if not include_transpose:
        return cost.edp
    t_cyc, t_pj = transpose_cost(plan, arch, word_bytes) if tcost is None else tcost
    return ((cost.energy_pj + t_pj) * 1e-12) * (
        (cost.latency_cycles + t_cyc) / cost.frequency_hz
    )


def run(include_transpose_cost: bool = True, store_dir: str | None = None,
        store_cap: int | None = None, backend: str = "numpy",
        sweep_kw: dict | None = None) -> dict:
    """The whole figure is ONE ``union_opt_sweep``: every (problem, side,
    space-mode, mapper) combination is a task. The heuristic and random
    searches over the same (problem, space) SHARE one engine -- the
    second mapper starts against a warm memo -- and the store/warmup are
    sweep-wide."""
    arch = cloud_accelerator(aspect=(32, 64))
    store = (
        ResultStore(store_dir, max_entries_per_space=store_cap)
        if store_dir
        else None
    )
    prob_rows = []
    tasks = []
    for name, tds, problem in tc_problems():
        plan = best_ttgt_plan(problem)
        gemm = plan.gemm_problem(word_bytes=1)
        t_cyc, t_pj = transpose_cost(plan, arch, word_bytes=1)
        prob_rows.append((name, tds, problem, gemm, plan, t_cyc, t_pj))
        for mode, cons in (("paper", PAPER_SPACE), ("union", None)):
            for side, prob in (("native", problem), ("ttgt", gemm)):
                for mp in _MAPPERS:
                    tasks.append(SweepTask(
                        prob, arch, mapper=mp, cost_model="timeloop",
                        metric="edp", constraints=cons,
                        tag=(name, mode, side, mp),
                    ))
    sweep = union_opt_sweep(tasks, engine_backend=backend, result_store=store,
                            **(sweep_kw or {}))
    by_tag = {t.tag: s for t, s in zip(tasks, sweep)}

    def _best_of(name, mode, side):
        return min(
            (by_tag[(name, mode, side, mp)] for mp in _MAPPERS),
            key=lambda s: s.cost.edp,
        )

    rows = []
    mappings = {}
    for name, tds, problem, gemm, plan, t_cyc, t_pj in prob_rows:
        row = {
            "problem": name, "tds": tds, "gemm_mnk": [plan.M, plan.N, plan.K],
            "transpose_elems": plan.transpose_elems,
            "transpose_cycles": t_cyc,
            "transpose_energy_pj": t_pj,
        }
        for mode, cons in (("paper", PAPER_SPACE), ("union", None)):
            native = _best_of(name, mode, "native")
            ttgt = _best_of(name, mode, "ttgt")
            ttgt_edp = ttgt_total_edp(ttgt.cost, plan, arch, include_transpose_cost,
                                      tcost=(t_cyc, t_pj))
            row[f"edp_native_{mode}"] = native.cost.edp
            row[f"edp_ttgt_{mode}"] = ttgt_edp
            row[f"edp_ttgt_gemm_only_{mode}"] = ttgt.cost.edp
            row[f"util_native_{mode}"] = native.cost.utilization
            row[f"winner_{mode}"] = (
                "ttgt" if ttgt_edp < native.cost.edp else "native"
            )
            row[f"search_native_{mode}"] = native.search.stats_dict()
            row[f"search_ttgt_{mode}"] = ttgt.search.stats_dict()
            if name == "intensli2" and tds == 16 and mode == "union":
                mappings["native"] = native.mapping.to_dict()
                mappings["native_loopnest"] = native.loop_nest()
                mappings["ttgt"] = ttgt.mapping.to_dict()
                mappings["ttgt_loopnest"] = ttgt.loop_nest()
        rows.append(row)
        print(f"[fig8] {name:10s} TDS={tds:<3d} "
              f"paper-space: native {row['edp_native_paper']:.3e} "
              f"(util {row['util_native_paper']:4.0%}) vs ttgt "
              f"{row['edp_ttgt_paper']:.3e} -> {row['winner_paper']:6s} | "
              f"union-space -> {row['winner_union']}")

    small = [r for r in rows if r["tds"] == 16]
    result = {
        "figure": "fig8",
        "accelerator": "cloud 32x64 (Table V)",
        "transpose_cost_included": include_transpose_cost,
        "rows": rows,
        "paper_claim_tds16_ttgt_wins": all(
            r["winner_paper"] == "ttgt" for r in small
        ),
        "union_space_changes_winner": sum(
            1 for r in rows if r["winner_paper"] != r["winner_union"]
        ),
        "fig9_mappings": mappings,
        "sweep": sweep.stats,
    }
    if store is not None:
        store.flush()
        if not deterministic_stats():  # hit counts shift with store warmth
            result["result_store"] = store.stats_dict()
            print(f"[fig8] result store: {result['result_store']}")
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "fig8.json").write_text(json.dumps(result, indent=1))
    print(f"[fig8] paper claim (TTGT wins at TDS=16, memory-target space): "
          f"{result['paper_claim_tds16_ttgt_wins']}")
    print(f"[fig8] beyond-paper: union map-space flips the winner on "
          f"{result['union_space_changes_winner']} of {len(rows)} rows")
    print("[fig9] optimal intensli2 native mapping (union space):\n"
          + mappings["native_loopnest"])
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--no-transpose-cost", action="store_true",
        help="omit the transposes' DRAM traffic from the TTGT side "
             "(reproduces the historical GEMM-only numbers)",
    )
    ap.add_argument("--store", default=None, metavar="DIR",
                    help="persistent cross-search ResultStore directory")
    ap.add_argument("--store-cap", type=int, default=None, metavar="N",
                    help="per-space LRU entry cap for the result store "
                         "(disk tier compacted at flush; default unbounded)")
    ap.add_argument("--backend", default="numpy",
                    choices=["numpy", "jax", "none"],
                    help="evaluation-engine array backend for the sweep")
    add_sweep_args(ap)
    args = ap.parse_args()
    run(include_transpose_cost=not args.no_transpose_cost, store_dir=args.store,
        store_cap=args.store_cap, backend=args.backend,
        sweep_kw=sweep_kwargs(args))
