"""Kernel co-design benchmark: planned-vs-default block configs + the
measured-vs-modeled calibration report (docs/codesign.md).

For every (kernel, shape) cell the bench plans a BlockConfig through the
unified ``codesign.plan`` path, predicts model cycles for BOTH the
planned and the legalized-default config (does the planner actually beat
the safe defaults in the model's own eyes?), MEASURES the emitted Pallas
kernel (interpret mode on CPU -- the CI configuration; real timing on a
TPU container), and records each measurement next to its prediction in a
:class:`~repro.codesign.calibrate.CalibrationTable`. The table's
per-kernel x shape model-error report (residual % after the per-kernel
calibration scale) is the validation artifact this bench publishes.

Output goes to ``experiments/benchmarks/kernels.json`` (full rows) and
``BENCH_kernels.json`` at the repo root (the CI-tracked summary,
uploaded as an artifact alongside the figure plots).

Usage:
    python benchmarks/kernels_bench.py [--smoke] [--repeats N]
                                       [--store DIR] [--calibration FILE]
                                       [--no-regress-check]
                                       [--regress-margin F]
                                       [--update-baseline]

``--smoke`` runs a reduced shape matrix that finishes in about a minute
and gates the DETERMINISTIC summary rows against the committed
``BENCH_kernels.json`` (warn-and-record bootstrap like
``mappers_bench``): the gate compares ``cycles_ratio`` (planned/default
predicted cycles -- pure model output, no timing noise) and fails when a
cell regresses past ``--regress-margin``; a missing baseline is recorded
from the run, and first-run cells are warned about and appended without
touching existing rows. Measured time and model-error rows are reported
and recorded but never gated -- interpret-mode wall time is container
noise. Smoke runs never replace existing baseline rows; pass
``--update-baseline`` to rewrite deliberately.

``--store DIR`` persists the plan cache across invocations (warm cells
skip the mapper search); ``--calibration FILE`` persists the calibration
table (CI uploads both artifacts).
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

OUT = Path("experiments/benchmarks")
ROOT_BENCH = Path("BENCH_kernels.json")

# (kernel, [shapes]) -- shape meaning is per KernelSpace: matmul (M,N,K),
# flash_attention (Sq,Skv,D), ssd_scan (hp,n)
SMOKE_MATRIX = [
    ("matmul", [(128, 128, 128), (256, 256, 128)]),
    ("flash_attention", [(128, 128, 64), (256, 256, 64)]),
    ("ssd_scan", [(64, 64), (64, 128)]),
]
FULL_MATRIX = [
    ("matmul", [(128, 128, 128), (256, 256, 128), (512, 512, 512),
                (1024, 1024, 1024)]),
    ("flash_attention", [(128, 128, 64), (256, 256, 64), (512, 512, 128),
                         (1024, 1024, 128)]),
    ("ssd_scan", [(64, 64), (64, 128), (128, 128), (256, 64)]),
]

_GATED_SECTION = "cycles_ratio"  # deterministic: pure model output
_SUMMARY_ROW_SECTIONS = (
    "cycles_ratio", "model_error_pct", "measured_s", "planned_config",
)


def _key(kernel: str, shape) -> str:
    return f"{kernel}/{'x'.join(map(str, shape))}"


def record_baseline_rows(summary: dict, base: dict, new_keys, baseline_path: Path):
    """Merge first-run cells into the committed baseline WITHOUT touching
    existing rows -- the bootstrap half of the warn-and-record contract."""
    for section in _SUMMARY_ROW_SECTIONS:
        rows = summary.get(section, {})
        dst = base.setdefault(section, {})
        for key in new_keys:
            if key in rows:
                dst[key] = rows[key]
    baseline_path.write_text(json.dumps(base, indent=1))
    return base


def check_regression(summary: dict, baseline_path: Path, margin: float) -> None:
    """Fail (SystemExit) when a planned config's model cycles regress past
    ``margin`` x the committed planned/default ratio. First runs bootstrap
    (warn-and-record, never crash or false-fail): a missing baseline file
    is recorded from this run; cells benchmarked for the first time are
    warned about and appended; existing rows are never overwritten."""
    if not baseline_path.exists():
        print(
            f"[kernels] no baseline at {baseline_path}; recording this run "
            "as the first baseline (no gate on a first run)"
        )
        baseline_path.write_text(json.dumps(summary, indent=1))
        return
    try:
        base = json.loads(baseline_path.read_text())
    except Exception as e:  # pragma: no cover - unreadable baseline
        print(f"[kernels] unreadable baseline ({e}); skipping regression gate")
        return
    if base.get("smoke") != summary["smoke"]:
        print("[kernels] baseline matrix differs (smoke); skipping gate")
        return
    failures = []
    new_keys = []
    for key, new_v in summary[_GATED_SECTION].items():
        old_v = base.get(_GATED_SECTION, {}).get(key)
        if old_v is None:
            new_keys.append(key)
        elif old_v and new_v > old_v * margin:
            failures.append(
                f"  {key}: planned/default cycles {new_v:.3f} > "
                f"{margin:.2f} x committed {old_v:.3f}"
            )
    if failures:
        raise SystemExit(
            "[kernels] planned-config REGRESSION vs committed "
            f"BENCH_kernels.json (margin {margin:.2f}):\n" + "\n".join(failures)
        )
    print(f"[kernels] regression gate OK (margin {margin:.2f} vs {baseline_path})")
    if new_keys:
        print(
            f"[kernels] WARNING: no committed baseline row for {new_keys} "
            "(first run of this kernel/shape); recording these rows"
        )
        record_baseline_rows(summary, base, new_keys, baseline_path)


def run(smoke: bool = False, repeats: int = 3, store_dir: str | None = None,
        calibration: str | None = None, regress_check: bool = True,
        regress_margin: float = 1.25, update_baseline: bool = False) -> dict:
    from repro import codesign
    from repro.codesign.calibrate import CalibrationTable, measure_kernel
    from repro.core.cost.store import ResultStore

    spaces = codesign.all_spaces()
    matrix = SMOKE_MATRIX if smoke else FULL_MATRIX
    store = ResultStore(store_dir) if store_dir else None
    table = CalibrationTable(calibration)
    codesign.reset_planner_stats()
    rows = []
    for kname, shapes in matrix:
        space = spaces[kname]
        for shape in shapes:
            p = codesign.plan(space, shape, store=store)
            default_cfg = space.legalize(space.default_config(shape), shape)
            d_cost = codesign.predict_cost(space, shape, default_cfg)
            p_cost = p.cost or codesign.predict_cost(space, shape, p.config)
            measured = measure_kernel(
                space, shape, p.config, interpret=True, repeats=repeats
            )
            table.record(
                kname, shape, p.config,
                codesign.planner._resolve_model(space, None).store_key_parts(),
                p_cost.latency_cycles, p_cost.frequency_hz, measured,
                interpret=True, repeats=repeats,
            )
            rows.append({
                "kernel": kname,
                "shape": list(shape),
                "planned_config": list(p.config),
                "default_config": list(default_cfg),
                "plan_source": p.source,
                "planned_cycles": p_cost.latency_cycles,
                "default_cycles": d_cost.latency_cycles,
                "cycles_ratio": p_cost.latency_cycles / d_cost.latency_cycles,
                "predicted_s": p_cost.latency_s,
                "measured_interpret_s": measured,
            })
    # per-kernel x shape model error AFTER the per-kernel calibration scale
    err_by_key = {
        _key(r["kernel"], r["shape"]): r["abs_error_pct"]
        for r in table.model_error_report()
    }
    scales = {
        k: (table.scale_for(k).scale if table.scale_for(k) else None)
        for k, _shapes in matrix
    }
    for r in rows:
        r["model_error_pct"] = err_by_key.get(_key(r["kernel"], r["shape"]))
        r["calibration_scale"] = scales[r["kernel"]]
        print(
            f"[kernels] {r['kernel']:16s} {str(tuple(r['shape'])):18s} "
            f"planned {str(tuple(r['planned_config'])):18s} "
            f"({r['plan_source']}) "
            f"cycles {r['planned_cycles']:.3e} "
            f"(default x{r['cycles_ratio']:.2f}) "
            f"measured {r['measured_interpret_s']*1e3:8.2f}ms "
            f"err {r['model_error_pct']:6.1f}%"
        )
    stats = codesign.planner_stats()
    print(f"[kernels] planner: {stats}")
    result = {
        "figure": "kernels",
        "smoke": smoke,
        "interpret": True,
        "rows": rows,
        "planner_stats": stats,
        "calibration": table.stats_dict(),
        "calibration_scales": scales,
    }
    if store is not None:
        store.flush()
        result["plan_store"] = store.stats_dict()
        print(f"[kernels] plan store: {result['plan_store']}")
    if calibration:
        table.flush()
        print(f"[kernels] calibration table: {calibration} "
              f"({table.stats_dict()})")
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "kernels.json").write_text(json.dumps(result, indent=1))
    summary = {
        "smoke": smoke,
        "interpret": True,
        "cycles_ratio": {
            _key(r["kernel"], r["shape"]): round(r["cycles_ratio"], 4)
            for r in rows
        },
        "model_error_pct": {
            _key(r["kernel"], r["shape"]): (
                round(r["model_error_pct"], 2)
                if r["model_error_pct"] is not None else None
            )
            for r in rows
        },
        "measured_s": {
            _key(r["kernel"], r["shape"]): round(r["measured_interpret_s"], 5)
            for r in rows
        },
        "planned_config": {
            _key(r["kernel"], r["shape"]): list(r["planned_config"])
            for r in rows
        },
        "calibration_scale": {
            k: (round(v, 5) if v is not None else None)
            for k, v in scales.items()
        },
        "plan_fallbacks": stats["plan_fallbacks"],
    }
    ROOT_BENCH_exists = ROOT_BENCH.exists()
    if smoke and regress_check and not update_baseline:
        check_regression(summary, ROOT_BENCH, regress_margin)
    elif smoke and update_baseline:
        print("[kernels] regression gate skipped: --update-baseline is a "
              "deliberate baseline rewrite")
    # Baseline rewrite rules mirror mappers_bench: a merely-passing smoke
    # run never replaces existing rows; full runs refuse to clobber a
    # committed smoke baseline unless --update-baseline.
    write_baseline = update_baseline
    if not update_baseline and not smoke:
        try:
            write_baseline = not json.loads(ROOT_BENCH.read_text()).get("smoke", False)
        except Exception:
            write_baseline = True  # absent/unreadable baseline: establish one
    if write_baseline:
        ROOT_BENCH.write_text(json.dumps(summary, indent=1))
    elif not smoke and ROOT_BENCH_exists:
        print(f"[kernels] baseline untouched ({ROOT_BENCH} is a smoke "
              "baseline; pass --update-baseline to replace it)")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced shape matrix + regression gate (CI)")
    ap.add_argument("--repeats", type=int, default=3,
                    help="best-of-N timing per cell")
    ap.add_argument("--store", default=None, metavar="DIR",
                    help="persistent plan-cache ResultStore directory")
    ap.add_argument("--calibration", default=None, metavar="FILE",
                    help="persist the calibration table to FILE")
    ap.add_argument("--no-regress-check", action="store_true",
                    help="skip the smoke-mode cycles_ratio gate vs "
                         "BENCH_kernels.json")
    ap.add_argument("--regress-margin", type=float, default=1.25,
                    help="fail when planned/default cycles exceed this "
                         "multiple of the committed ratio (smoke only)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite BENCH_kernels.json from this run")
    args = ap.parse_args()
    run(smoke=args.smoke, repeats=args.repeats, store_dir=args.store,
        calibration=args.calibration,
        regress_check=not args.no_regress_check,
        regress_margin=args.regress_margin,
        update_baseline=args.update_baseline)
