"""Shared CLI plumbing for the fault-tolerant sweep executor.

Every figure benchmark drives ``union_opt_sweep``; this module gives them
one flag vocabulary for the executor knobs (``--workers``, ``--pool``,
``--group-timeout``, ``--group-retries``, ``--journal``, ``--resume``)
and one place for the deterministic-stats convention the crash/resume
byte-identity check relies on (``UNION_DETERMINISTIC_STATS``: emit only
warm/cold-invariant counters and omit the ``result_store`` block, so a
killed-and-resumed figure run serializes byte-identically to an
uninterrupted one).
"""

from __future__ import annotations

import argparse
import os


def add_sweep_args(ap: argparse.ArgumentParser) -> None:
    """Add the sweep-executor flags shared by all figure benchmarks."""
    ap.add_argument("--workers", type=int, default=0, metavar="N",
                    help="concurrent engine-group dispatches (0/1 = serial; "
                         ">1 runs independent groups on a worker pool)")
    ap.add_argument("--pool", default="auto",
                    choices=["auto", "thread", "process", "serial"],
                    help="worker pool kind for --workers > 1 (auto = "
                         "process: spawned interpreters, the load-bearing "
                         "path since the numpy engine is GIL-bound)")
    ap.add_argument("--group-timeout", type=float, default=None,
                    metavar="SECS",
                    help="per-group watchdog deadline; a hung dispatch is "
                         "abandoned and retried (default: no deadline)")
    ap.add_argument("--group-retries", type=int, default=2, metavar="N",
                    help="bounded retries per group before the sweep fails")
    ap.add_argument("--journal", default=None, metavar="FILE",
                    help="crash-safe sweep journal (atomic per-group "
                         "flush); enables --resume")
    ap.add_argument("--resume", action="store_true",
                    help="replay groups already completed in --journal "
                         "instead of re-searching them (warm-starts the "
                         "rest from the result store)")


def sweep_kwargs(args: argparse.Namespace) -> dict:
    """``union_opt_sweep`` executor kwargs from parsed args."""
    if args.resume and not args.journal:
        raise SystemExit("--resume requires --journal FILE")
    return {
        "workers": args.workers,
        "pool": args.pool,
        "group_timeout_s": args.group_timeout,
        "max_group_retries": args.group_retries,
        "journal": args.journal,
        "resume": args.resume,
    }


def deterministic_stats() -> bool:
    """True when figure JSONs must contain only run-invariant content
    (see ``SearchResult.stats_dict``); figure scripts then omit their
    ``result_store`` block, whose hit/entry counts shift with warmth."""
    return bool(os.environ.get("UNION_DETERMINISTIC_STATS"))
