"""Cross-figure aggregation + plots over the uniform ``stats_dict()`` JSON.

Every figure benchmark (fig3/fig8/fig10/fig11) and ``mappers_bench`` writes
its results to ``experiments/benchmarks/*.json`` with engine-counter blocks
in one shared schema (``SearchResult.stats_dict()``: evals_per_s, admit_s /
score_s phase split, cache/store/pruned counters). This script walks those
files, flattens every embedded search block into rows tagged with its
figure and experimental point, and renders:

  * ``evals_per_s.png``   -- throughput distribution per figure (plus the
    mappers-bench per-(backend, mapper) bars);
  * ``edp_summary.png``   -- EDP comparisons per figure (fig8 native vs
    TTGT per mode; fig10 best-aspect EDP per workload; fig11 EDP vs
    bandwidth curves);
  * ``figures_summary.json`` -- the flattened rows + per-figure throughput
    aggregates (always written, even without matplotlib), plus a
    ``robustness`` section with the fault-tolerant sweep executor's
    ledger per figure (workers/pool, retries, timeouts,
    backend_fallbacks, stragglers, replayed groups, per-group
    wall-clock; see ``docs/sweep_service.md``).

Usage:
    python benchmarks/plot_figures.py [--dir experiments/benchmarks]
                                      [--out experiments/benchmarks/plots]

Plots degrade gracefully: a missing figure JSON is skipped with a note,
and without matplotlib only the JSON summary is produced.
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Dict, List, Optional


def _search_rows(figure: str, payload: dict) -> List[dict]:
    """Flatten every stats_dict block in one figure's JSON into tagged
    rows ``{figure, point, evals_per_s, ...}``."""
    rows: List[dict] = []

    def add(point: str, block: Optional[dict], extra: Optional[dict] = None):
        if not isinstance(block, dict) or "evals_per_s" not in block:
            return
        row = {"figure": figure, "point": point}
        row.update(block)
        if extra:
            row.update(extra)
        rows.append(row)

    if figure == "fig3":
        add("union_opt", payload.get("search"),
            {"edp": payload.get("union_opt_edp")})
    elif figure == "fig8":
        for r in payload.get("rows", []):
            for mode in ("paper", "union"):
                for side in ("native", "ttgt"):
                    add(
                        f"{r['problem']}/{mode}/{side}",
                        r.get(f"search_{side}_{mode}"),
                        {"edp": r.get(f"edp_{side}_{mode}")},
                    )
    elif figure == "fig10":
        for tag in ("edge", "cloud"):
            for wname, row in payload.get(tag, {}).items():
                for aspect, cell in row.items():
                    add(f"{tag}/{wname}/{aspect}", cell.get("search"),
                        {"edp": cell.get("edp")})
    elif figure == "fig11":
        bws = payload.get("bandwidths_gbps", [])
        for wname, row in payload.get("rows", {}).items():
            for i, blk in enumerate(row.get("search", [])):
                bw = bws[i] if i < len(bws) else i
                add(f"{wname}/{bw}gbps", blk,
                    {"edp": row["edp"][i] if i < len(row.get("edp", [])) else None})
    elif figure == "mappers":
        for r in payload.get("rows", []):
            point = f"{r.get('backend', '?')}/{r['cost_model']}/{r['mapper']}"
            keys = (
                "evals_per_s", "cache_hit_rate", "pruned", "store_hits",
                "admit_s", "score_s", "considered", "edp",
            )
            rows.append(
                {"figure": "mappers", "point": point}
                | {k: r.get(k) for k in keys}
            )
    elif figure == "model":
        # whole-model streams: ONE shared sweep, so throughput/store
        # counters live in the sweep_stats block, per-model EDP in rows
        sweep = payload.get("sweep_stats", {})
        for r in payload.get("rows", []):
            rows.append({
                "figure": "model",
                "point": f"{r['model']}/{r.get('shape', '?')}",
                "edp": r.get("edp"),
                "latency_s": r.get("latency_s"),
                "energy_j": r.get("energy_j"),
                "roles": r.get("roles"),
                "n_unique_ops": r.get("n_unique_ops"),
                "evals_per_s": sweep.get("evals_per_s"),
                "store_hits": sweep.get("store_hits"),
                "cache_hits": sweep.get("cache_hits"),
            })
    return rows


_ROBUSTNESS_KEYS = (
    "workers", "pool", "attempts", "retries", "timeouts",
    "backend_fallbacks", "stragglers", "replayed_groups",
)


def _robustness(figure: str, sweep: Optional[dict]) -> Optional[dict]:
    """Pull the fault-tolerant executor's ledger out of a figure's
    ``sweep`` stats block (``union_opt_sweep``; see
    ``docs/sweep_service.md``). Deterministic-stats runs strip most of
    the ledger; whatever survives is reported."""
    if not isinstance(sweep, dict):
        return None
    row = {"figure": figure, "groups": sweep.get("engines")}
    row.update({k: sweep[k] for k in _ROBUSTNESS_KEYS if k in sweep})
    walls = [
        g["wall_s"] for g in sweep.get("group_wall") or []
        if isinstance(g, dict) and "wall_s" in g
    ]
    if walls:
        row["group_wall_max_s"] = round(max(walls), 3)
        row["group_wall_mean_s"] = round(sum(walls) / len(walls), 3)
        row["group_stragglers"] = sum(
            1 for g in sweep.get("group_wall") or [] if g.get("straggler")
        )
    if len(row) <= 2 and row.get("groups") is None:
        return None
    return row


def collect(bench_dir: Path):
    out: Dict[str, List[dict]] = {}
    robustness: List[dict] = []
    for figure in ("fig3", "fig8", "fig10", "fig11", "mappers", "model"):
        f = bench_dir / f"{figure}.json"
        if not f.exists():
            print(f"[plots] {f} missing -- run its benchmark first; skipped")
            continue
        try:
            payload = json.loads(f.read_text())
        except Exception as e:
            print(f"[plots] {f} unreadable ({e}); skipped")
            continue
        rows = _search_rows(figure, payload)
        if rows:
            out[figure] = rows
        rob = _robustness(figure, payload.get("sweep") or payload.get("sweep_stats"))
        if rob:
            robustness.append(rob)
    # the concurrent-sweep bench reports its ledger at the top level
    f = bench_dir / "sweep_service.json"
    if f.exists():
        try:
            payload = json.loads(f.read_text())
            row = {"figure": "sweep_bench"}
            row.update({
                k: payload[k]
                for k in ("groups", "cores", "workers", "pool", "retries",
                          "timeouts", "backend_fallbacks", "stragglers",
                          "ratio")
                if k in payload
            })
            robustness.append(row)
        except Exception as e:
            print(f"[plots] {f} unreadable ({e}); skipped")
    return out, robustness


def _aggregate(rows_by_fig: Dict[str, List[dict]]) -> dict:
    agg = {}
    for figure, rows in rows_by_fig.items():
        vals = [r["evals_per_s"] for r in rows if r.get("evals_per_s")]
        if not vals:
            continue
        agg[figure] = {
            "searches": len(rows),
            "evals_per_s_min": min(vals),
            "evals_per_s_max": max(vals),
            "evals_per_s_mean": round(sum(vals) / len(vals), 1),
            "store_hits": sum(int(r.get("store_hits") or 0) for r in rows),
            "pruned": sum(int(r.get("pruned") or 0) for r in rows),
        }
    return agg


def _plot(rows_by_fig: Dict[str, List[dict]], out_dir: Path) -> List[str]:
    try:
        import matplotlib

        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except Exception as e:  # pragma: no cover - plotting is best-effort
        print(f"[plots] matplotlib unavailable ({e}); JSON summary only")
        return []
    written = []

    # ---- throughput overview -------------------------------------- #
    fig, axes = plt.subplots(1, 2, figsize=(13, 4.5))
    names = [f for f in rows_by_fig if f != "mappers"]
    series = [
        [r["evals_per_s"] for r in rows_by_fig[f] if r.get("evals_per_s")]
        for f in names
    ]
    if names:
        axes[0].boxplot(series, tick_labels=names)
        axes[0].set_ylabel("evals / s")
        axes[0].set_title("search throughput per figure benchmark")
        axes[0].grid(axis="y", alpha=0.3)
    mrows = rows_by_fig.get("mappers", [])
    if mrows:
        pts = [r["point"] for r in mrows]
        axes[1].barh(pts, [r["evals_per_s"] for r in mrows])
        axes[1].set_xlabel("evals / s")
        axes[1].set_title("mappers_bench rows (backend/model/mapper)")
        axes[1].grid(axis="x", alpha=0.3)
    fig.tight_layout()
    p = out_dir / "evals_per_s.png"
    fig.savefig(p, dpi=120)
    plt.close(fig)
    written.append(str(p))

    # ---- EDP comparisons ------------------------------------------- #
    fig, axes = plt.subplots(1, 3, figsize=(16, 4.5))
    f8 = rows_by_fig.get("fig8", [])
    if f8:
        by = {r["point"]: r.get("edp") for r in f8}
        probs = sorted({p.split("/")[0] for p in by})
        x = range(len(probs))
        for i, (mode, side, style) in enumerate(
            (("paper", "native", "o-"), ("paper", "ttgt", "o--"),
             ("union", "native", "s-"), ("union", "ttgt", "s--"))
        ):
            ys = [by.get(f"{p}/{mode}/{side}") for p in probs]
            axes[0].plot(x, ys, style, label=f"{side} ({mode} space)")
        axes[0].set_xticks(list(x), probs, rotation=30, ha="right")
        axes[0].set_yscale("log")
        axes[0].set_ylabel("EDP (J*s)")
        axes[0].set_title("fig8: native vs TTGT")
        axes[0].legend(fontsize=8)
    f10 = rows_by_fig.get("fig10", [])
    if f10:
        best: Dict[str, float] = {}
        for r in f10:
            tag, wname, _aspect = r["point"].split("/")
            k = f"{tag}/{wname}"
            if r.get("edp") is not None:
                best[k] = min(best.get(k, float("inf")), r["edp"])
        axes[1].barh(list(best), list(best.values()))
        axes[1].set_xscale("log")
        axes[1].set_xlabel("best-aspect EDP (J*s)")
        axes[1].set_title("fig10: best aspect per workload")
    f11 = rows_by_fig.get("fig11", [])
    if f11:
        curves: Dict[str, List[tuple]] = {}
        for r in f11:
            wname, bw = r["point"].rsplit("/", 1)
            curves.setdefault(wname, []).append(
                (float(bw.replace("gbps", "")), r.get("edp"))
            )
        for wname, pts in curves.items():
            pts.sort()
            axes[2].plot([b for b, _ in pts], [e for _, e in pts], "o-",
                         label=wname)
        axes[2].set_xscale("log")
        axes[2].set_yscale("log")
        axes[2].set_xlabel("fill bandwidth (GB/s)")
        axes[2].set_ylabel("EDP (J*s)")
        axes[2].set_title("fig11: EDP vs chiplet bandwidth")
        axes[2].legend(fontsize=8)
    fig.tight_layout()
    p = out_dir / "edp_summary.png"
    fig.savefig(p, dpi=120)
    plt.close(fig)
    written.append(str(p))

    # ---- whole-model stacked EDP by role --------------------------- #
    mrows = [r for r in rows_by_fig.get("model", []) if r.get("roles")]
    if mrows:
        roles = sorted({role for r in mrows for role in r["roles"]})
        fig, ax = plt.subplots(figsize=(9, 4.5))
        xs = range(len(mrows))
        bottom = [0.0] * len(mrows)
        for role in roles:
            # role's share of end-to-end EDP: its energy x total latency,
            # so the stack sums exactly to EDP = E_total x L_total
            vals = [
                r["roles"].get(role, {}).get("energy_j", 0.0)
                * (r.get("latency_s") or 0.0)
                for r in mrows
            ]
            ax.bar(xs, vals, bottom=bottom, label=role)
            bottom = [b + v for b, v in zip(bottom, vals)]
        ax.set_xticks(list(xs), [r["point"] for r in mrows],
                      rotation=20, ha="right")
        ax.set_ylabel("EDP (J*s)")
        ax.set_title("whole-model end-to-end EDP by role (one-sweep streams)")
        ax.legend(fontsize=8, ncol=2)
        ax.grid(axis="y", alpha=0.3)
        fig.tight_layout()
        p = out_dir / "model_edp_roles.png"
        fig.savefig(p, dpi=120)
        plt.close(fig)
        written.append(str(p))
    return written


def run(bench_dir: str = "experiments/benchmarks",
        out_dir: str | None = None) -> dict:
    bdir = Path(bench_dir)
    odir = Path(out_dir) if out_dir else bdir / "plots"
    odir.mkdir(parents=True, exist_ok=True)
    rows_by_fig, robustness = collect(bdir)
    agg = _aggregate(rows_by_fig)
    summary = {
        "figures": sorted(rows_by_fig),
        "aggregates": agg,
        "robustness": robustness,
        "rows": [r for rows in rows_by_fig.values() for r in rows],
    }
    (odir / "figures_summary.json").write_text(json.dumps(summary, indent=1))
    plots = _plot(rows_by_fig, odir)
    summary["plots"] = plots
    for figure, a in agg.items():
        print(
            f"[plots] {figure:8s} {a['searches']:3d} searches, evals/s "
            f"{a['evals_per_s_min']:>9,.0f} .. {a['evals_per_s_max']:>9,.0f} "
            f"(mean {a['evals_per_s_mean']:>9,.0f}), store hits "
            f"{a['store_hits']}, pruned {a['pruned']}"
        )
    for r in robustness:
        counters = ", ".join(
            f"{k} {r[k]}" for k in ("retries", "timeouts",
                                    "backend_fallbacks", "stragglers",
                                    "replayed_groups")
            if k in r
        )
        print(f"[plots] robustness {r['figure']:12s} "
              f"groups {r.get('groups', '?')}, workers "
              f"{r.get('workers', '?')} ({r.get('pool', '?')})"
              + (f", {counters}" if counters else ""))
    print(f"[plots] summary -> {odir / 'figures_summary.json'}"
          + (f", plots -> {', '.join(plots)}" if plots else " (no plots)"))
    return summary


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/benchmarks",
                    help="directory holding the figure JSONs")
    ap.add_argument("--out", default=None,
                    help="output directory (default <dir>/plots)")
    args = ap.parse_args()
    run(bench_dir=args.dir, out_dir=args.out)
