"""Concurrent sweep-service benchmark: workers=1 vs workers=N wall-clock.

A fig10-style multi-group task list (several GEMM layers x several edge
aspect ratios, three mappers per space) is run twice through
``union_opt_sweep``: once serial (``workers=1``) and once on the
fault-tolerant group executor's process pool (``workers=N``, spawned
interpreters, GIL-free -- see ``docs/sweep_service.md``). The run asserts
the two sweeps return identical mappings and costs (the executor must be
a pure scheduling change) and reports the wall-clock ratio.

The rows land in ``BENCH_mappers.json`` under the ``sweep_wall`` key as
NON-GATING data: the smoke-mode evals/s regression gate only reads the
``evals_per_s`` section, so these rows track the concurrency trend
without adding a flaky wall-clock floor. ``--check`` turns the ratio
into a hard assertion for CI -- workers=N <= ``--margin`` x workers=1
when the runner exposes >= 2 CPUs; on a single-CPU runner a parallel
speedup is physically impossible (the pool time-slices one core), so
the check degrades to an overhead bound (<= ``--overhead-margin`` x),
still catching a serialization bug that would make the pool pay more
than spawn cost.

Usage:
    python benchmarks/sweep_bench.py [--smoke] [--workers N] [--check]
                                     [--margin 0.6] [--no-bench-write]
"""

from __future__ import annotations

import argparse
import json
import os
import time
from pathlib import Path

from benchmarks.workloads import dnn_layers
from repro.core.architecture import edge_accelerator
from repro.core.optimizer import SweepTask, union_opt_sweep

OUT = Path("experiments/benchmarks")
ROOT_BENCH = Path("BENCH_mappers.json")

# Per-space mapper trio: enough per-group work that a spawned worker's
# import cost amortizes, small enough for a CI smoke lane.
_SMOKE = {"names": ["DLRM-1", "BERT-1", "DLRM-2", "BERT-2"],
          "aspects": [(16, 16), (4, 64)],
          "samples": 25000, "generations": 60}
_FULL = {"names": ["DLRM-1", "DLRM-2", "DLRM-3",
                   "BERT-1", "BERT-2", "BERT-3"],
         "aspects": [(16, 16), (8, 32), (4, 64), (2, 128)],
         "samples": 40000, "generations": 120}


def build_tasks(smoke: bool = True) -> list:
    cfg = _SMOKE if smoke else _FULL
    layers = dnn_layers()
    tasks = []
    for wname in cfg["names"]:
        for aspect in cfg["aspects"]:
            arch = edge_accelerator(aspect=aspect)
            problem = layers[wname]
            atag = "x".join(map(str, aspect))
            for mp, kw in (
                ("heuristic", {}),
                ("random", {"samples": cfg["samples"]}),
                ("genetic", {"generations": cfg["generations"]}),
            ):
                tasks.append(SweepTask(
                    problem, arch, mapper=mp, cost_model="timeloop",
                    metric="edp", mapper_kw=kw, tag=(wname, atag, mp),
                ))
    return tasks


def _timed(tasks, workers: int, pool: str):
    t0 = time.time()
    sweep = union_opt_sweep(tasks, workers=workers, pool=pool)
    return time.time() - t0, sweep


def run(smoke: bool = True, workers: int = 4, pool: str = "process",
        margin: float = 0.6, overhead_margin: float = 1.8,
        check: bool = False, bench_write: bool = True) -> dict:
    try:
        cores = len(os.sched_getaffinity(0))
    except AttributeError:  # pragma: no cover - non-linux
        cores = os.cpu_count() or 1
    tasks = build_tasks(smoke)
    wall1, serial = _timed(tasks, 1, "serial")
    walln, conc = _timed(tasks, workers, pool)
    mismatches = [
        t.tag for t, a, b in zip(tasks, serial, conc)
        if a.cost.edp != b.cost.edp
        or a.mapping.to_dict() != b.mapping.to_dict()
    ]
    if mismatches:
        raise SystemExit(
            f"[sweep_bench] concurrent sweep DIVERGED from serial on "
            f"{len(mismatches)} task(s): {mismatches[:5]}"
        )
    ratio = walln / wall1 if wall1 else float("inf")
    stats = conc.stats
    result = {
        "figure": "sweep_bench",
        "smoke": smoke,
        "tasks": len(tasks),
        "groups": stats.get("engines"),
        "cores": cores,
        "workers": workers,
        "pool": stats.get("pool", pool),
        "wall_s_workers1": round(wall1, 3),
        f"wall_s_workers{workers}": round(walln, 3),
        "ratio": round(ratio, 3),
        "identical_results": True,
        "retries": stats.get("retries", 0),
        "timeouts": stats.get("timeouts", 0),
        "backend_fallbacks": stats.get("backend_fallbacks", 0),
        "stragglers": stats.get("stragglers", 0),
        "group_wall_s": stats.get("group_wall"),
    }
    print(f"[sweep_bench] {len(tasks)} tasks / {result['groups']} groups "
          f"on {cores} core(s): workers=1 {wall1:.2f}s vs "
          f"workers={workers} ({result['pool']}) {walln:.2f}s -> "
          f"ratio {ratio:.2f} (identical results)")
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "sweep_service.json").write_text(json.dumps(result, indent=1))
    if bench_write:
        # merge-only under our own key: the evals/s gate sections and
        # their committed floors are never touched
        try:
            base = json.loads(ROOT_BENCH.read_text())
        except Exception:
            base = {}
        base["sweep_wall"] = {
            "tasks": len(tasks), "groups": result["groups"],
            "cores": cores, "workers": workers, "pool": result["pool"],
            "wall_s_workers1": result["wall_s_workers1"],
            f"wall_s_workers{workers}": result[f"wall_s_workers{workers}"],
            "ratio": result["ratio"],
        }
        ROOT_BENCH.write_text(json.dumps(base, indent=1))
        print(f"[sweep_bench] recorded non-gating sweep_wall rows in "
              f"{ROOT_BENCH}")
    if check:
        # a speedup needs real cores; a single-CPU runner time-slices the
        # pool, so only bound the dispatch/spawn overhead there
        eff = margin if cores >= 2 else overhead_margin
        kind = "speedup" if cores >= 2 else "overhead (1 core)"
        if ratio > eff:
            raise SystemExit(
                f"[sweep_bench] concurrency {kind} REGRESSION: "
                f"workers={workers} wall {walln:.2f}s > {eff:.0%} of "
                f"workers=1 wall {wall1:.2f}s"
            )
        print(f"[sweep_bench] concurrency {kind} check OK "
              f"(ratio {ratio:.2f} <= margin {eff:.0%})")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced task list for the CI lane")
    ap.add_argument("--workers", type=int, default=4,
                    help="group-executor pool size for the concurrent run")
    ap.add_argument("--pool", default="process",
                    choices=["process", "thread", "auto"],
                    help="pool flavor for the concurrent run")
    ap.add_argument("--margin", type=float, default=0.6,
                    help="--check fails when workers=N wall exceeds this "
                         "fraction of the workers=1 wall (>= 2 CPUs)")
    ap.add_argument("--overhead-margin", type=float, default=1.8,
                    help="fallback --check bound on a single-CPU runner, "
                         "where parallel speedup is impossible")
    ap.add_argument("--check", action="store_true",
                    help="assert the concurrency ratio meets --margin")
    ap.add_argument("--no-bench-write", action="store_true",
                    help="do not record sweep_wall rows in BENCH_mappers.json")
    args = ap.parse_args()
    run(smoke=args.smoke, workers=args.workers, pool=args.pool,
        margin=args.margin, overhead_margin=args.overhead_margin,
        check=args.check, bench_write=not args.no_bench_write)
