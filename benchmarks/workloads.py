"""Paper workload tables (Tables III & IV) as Union Problems.

Table III: TCCG tensor contractions with the reference TDS sizes.
Table IV:  DNN layers from MLPerf models (ResNet50 CONV / DLRM & BERT GEMM).
The paper costs everything with uint8 MACs (word_bytes=1).
"""

from __future__ import annotations

from typing import Dict, List, Tuple

from repro.core.problem import Problem

WORD = 1  # uint8 (paper Sec. V)


def dnn_layers() -> Dict[str, Problem]:
    """Paper Table IV."""
    out: Dict[str, Problem] = {}
    # CONV layers: paper table gives activation sizes; same-padding => X,Y
    # are also the output sizes Problem.conv2d expects.
    out["ResNet50-1"] = Problem.conv2d(32, 64, 64, 56, 56, 1, 1, name="ResNet50-1", word_bytes=WORD)
    out["ResNet50-2"] = Problem.conv2d(32, 64, 64, 56, 56, 3, 3, name="ResNet50-2", word_bytes=WORD)
    out["ResNet50-3"] = Problem.conv2d(32, 512, 1024, 14, 14, 1, 1, name="ResNet50-3", word_bytes=WORD)
    for name, (n, nin, non) in {
        "DLRM-1": (512, 1024, 1024),
        "DLRM-2": (512, 1024, 64),
        "DLRM-3": (512, 2048, 2048),
        "BERT-1": (256, 768, 768),
        "BERT-2": (256, 3072, 768),
        "BERT-3": (256, 768, 3072),
    }.items():
        out[name] = Problem.gemm(n, non, nin, name=name, word_bytes=WORD)
    return out


def tc_problems() -> List[Tuple[str, int, Problem]]:
    """Paper Table III / Fig. 8: (name, TDS, problem)."""
    probs = []
    for tds in (16, 64):
        probs.append(("intensli2", tds, Problem.tc_intensli2(tds, word_bytes=WORD)))
        probs.append(("ccsd7", tds, Problem.tc_ccsd7(tds, word_bytes=WORD)))
    for tds in (16, 32):
        probs.append(("ccsd-t4", tds, Problem.tc_ccsd_t4(tds, word_bytes=WORD)))
    return probs


EDGE_ASPECTS = [(1, 256), (2, 128), (4, 64), (8, 32), (16, 16)]
CLOUD_ASPECTS = [(1, 2048), (2, 1024), (4, 512), (8, 256), (16, 128), (32, 64)]
