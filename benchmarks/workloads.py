"""Paper workload tables (Tables III & IV) as Union Problems.

Table III: TCCG tensor contractions with the reference TDS sizes.
Table IV:  DNN layers from MLPerf models (ResNet50 CONV / DLRM & BERT GEMM).
The paper costs everything with uint8 MACs (word_bytes=1).

All problems are constructed through the shared IR-routed builders in
``repro.core.opstream`` -- the same LayerOp -> generic -> affine -> Problem
path the whole-model streams use -- and are bit-identical to the historical
``Problem.gemm``/``Problem.conv2d``/``Problem.tc_*`` constructors
(asserted in tests/test_opstream.py).
"""

from __future__ import annotations

from typing import Dict, List, Tuple

from repro.core.opstream import (
    build_conv2d,
    build_gemm,
    build_tc_ccsd7,
    build_tc_ccsd_t4,
    build_tc_intensli2,
)
from repro.core.problem import Problem

WORD = 1  # uint8 (paper Sec. V)


def dnn_layers() -> Dict[str, Problem]:
    """Paper Table IV."""
    out: Dict[str, Problem] = {}
    # CONV layers: paper table gives activation sizes; same-padding => X,Y
    # are also the output sizes the conv2d builder expects.
    out["ResNet50-1"] = build_conv2d(32, 64, 64, 56, 56, 1, 1, name="ResNet50-1", word_bytes=WORD)
    out["ResNet50-2"] = build_conv2d(32, 64, 64, 56, 56, 3, 3, name="ResNet50-2", word_bytes=WORD)
    out["ResNet50-3"] = build_conv2d(32, 512, 1024, 14, 14, 1, 1, name="ResNet50-3", word_bytes=WORD)
    for name, (n, nin, non) in {
        "DLRM-1": (512, 1024, 1024),
        "DLRM-2": (512, 1024, 64),
        "DLRM-3": (512, 2048, 2048),
        "BERT-1": (256, 768, 768),
        "BERT-2": (256, 3072, 768),
        "BERT-3": (256, 768, 3072),
    }.items():
        out[name] = build_gemm(n, non, nin, name=name, word_bytes=WORD)
    return out


def tc_problems() -> List[Tuple[str, int, Problem]]:
    """Paper Table III / Fig. 8: (name, TDS, problem)."""
    probs = []
    for tds in (16, 64):
        probs.append(("intensli2", tds, build_tc_intensli2(tds, word_bytes=WORD)))
        probs.append(("ccsd7", tds, build_tc_ccsd7(tds, word_bytes=WORD)))
    for tds in (16, 32):
        probs.append(("ccsd-t4", tds, build_tc_ccsd_t4(tds, word_bytes=WORD)))
    return probs


EDGE_ASPECTS = [(1, 256), (2, 128), (4, 64), (8, 32), (16, 16)]
CLOUD_ASPECTS = [(1, 2048), (2, 1024), (4, 512), (8, 256), (16, 128), (32, 64)]
