"""Whole-model end-to-end bench: ModelConfig -> OpStream -> one sweep -> EDP.

Lowers each model (default: one dense-attention, one MoE, one SSM-hybrid)
into its deduplicated operator stream (``repro.core.opstream``), drives
EVERY stream's mappable ops through ONE ``union_opt_sweep`` call -- so
content-equal ops across models share engine groups, memo caches and the
persistent ResultStore -- and aggregates multiplicity-weighted per-op
costs into end-to-end latency/energy/EDP per model, with a stacked
per-role breakdown and the stream-vs-MODEL_FLOPS reconciliation ratio.

Output goes to ``experiments/benchmarks/model.json`` (full rows) and
``BENCH_model.json`` at the repo root (the CI-tracked summary).

Usage:
    python benchmarks/model_bench.py [--smoke] [--models A,B] [--shape S]
                                     [--backend numpy] [--store DIR]
                                     [--no-regress-check] [--update-baseline]
                                     [--workers N] [--journal FILE] [--resume]

``--smoke`` uses the ``_smoke`` reduced configs on a small prefill shape
(finishes in seconds; the CI trajectory run). In smoke mode the run
asserts evals/s has not regressed against the committed
``BENCH_model.json`` with mappers_bench's warn-and-record bootstrap
contract: a missing baseline is recorded from the run, rows benchmarked
for the first time are warned about and appended (never overwriting the
committed floor), and warm-store rows (``--store``) never gate or write
the baseline -- they are incomparable to cold runs, but their nonzero
``store_hits`` are exactly the cross-run sharing the CI cache exists for.

Dryrun artifacts (``experiments/dryrun/<model>__<shape>__16x16.json``),
when present, contribute the MEASURED hloparse collective term to each
model's end-to-end latency (``opstream.measured_collective_s``) and an
artifact-reconciliation row; absent artifacts degrade to collective_s=0
with a note, never an error.
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

from benchmarks.sweep_cli import add_sweep_args, deterministic_stats, sweep_kwargs
from repro.configs.base import SHAPES, ShapeConfig, get_config
from repro.core.architecture import cloud_accelerator
from repro.core.cost import ResultStore
from repro.core.optimizer import union_opt_sweep
from repro.core.opstream import (
    RECONCILE_BAND,
    aggregate_stream_costs,
    artifact_path,
    build_opstream,
    measured_collective_s,
    reconcile_model_flops,
    reconcile_with_artifact,
    stream_sweep_tasks,
)

OUT = Path("experiments/benchmarks")
ROOT_BENCH = Path("BENCH_model.json")

#: one dense-attention, one MoE, one SSM/attention hybrid (acceptance floor)
MODELS = ["qwen3-0.6b", "deepseek-v2-lite-16b", "zamba2-2.7b"]

SMOKE_SHAPE = ShapeConfig("smoke_prefill", 256, 2, "prefill")


def record_baseline_rows(summary: dict, base: dict, new_keys, baseline_path: Path):
    """Bootstrap half of the warn-and-record contract (mappers_bench
    semantics): append first-run rows without touching committed floors."""
    for section in ("evals_per_s", "edp", "store_hits"):
        rows = summary.get(section, {})
        dst = base.setdefault(section, {})
        for key in new_keys:
            if key in rows:
                dst.setdefault(key, rows[key])
    baseline_path.write_text(json.dumps(base, indent=1))
    return base


def check_regression(summary: dict, baseline_path: Path, margin: float) -> None:
    """Smoke-mode evals/s gate vs the committed ``BENCH_model.json``.
    Missing baseline -> record; matrix mismatch -> skip; new rows ->
    warn-and-record; a row below ``margin`` x its floor -> SystemExit."""
    if not baseline_path.exists():
        print(f"[model] no baseline at {baseline_path}; recording this run "
              "as the first baseline (no gate on a first run)")
        baseline_path.write_text(json.dumps(summary, indent=1))
        return
    try:
        base = json.loads(baseline_path.read_text())
    except Exception as e:  # pragma: no cover - unreadable baseline
        print(f"[model] unreadable baseline ({e}); skipping regression gate")
        return
    if base.get("smoke") != summary["smoke"] or base.get("shape") != summary["shape"]:
        print("[model] baseline matrix differs (smoke/shape); skipping gate")
        return
    failures, new_keys = [], []
    for key, new_v in summary["evals_per_s"].items():
        old_v = base.get("evals_per_s", {}).get(key)
        if old_v is None:
            new_keys.append(key)
        elif old_v and new_v < old_v * margin:
            failures.append(
                f"  {key}: {new_v:,.0f} evals/s < {margin:.0%} of committed "
                f"{old_v:,.0f} (floor {old_v * margin:,.0f})")
    if failures:
        raise SystemExit(
            "[model] evals/s REGRESSION vs committed BENCH_model.json "
            f"(margin {margin:.0%}):\n" + "\n".join(failures))
    print(f"[model] regression gate OK (margin {margin:.0%} vs {baseline_path})")
    for key in summary.get("edp", {}):
        if key not in base.get("edp", {}) and key not in new_keys:
            new_keys.append(key)
    if new_keys:
        print(f"[model] WARNING: no committed baseline row for {new_keys} "
              "(first run of this model/backend); recording these rows")
        record_baseline_rows(summary, base, new_keys, baseline_path)


def run(smoke: bool = False, models=None, shape_name: str | None = None,
        backend: str = "numpy", store_dir: str | None = None,
        regress_check: bool = True, regress_margin: float = 0.5,
        update_baseline: bool = False, sweep_kw: dict | None = None,
        art_dir: str = "experiments/dryrun") -> dict:
    models = list(models or MODELS)
    if smoke and shape_name is None:
        shape = SMOKE_SHAPE
    else:
        shape = SHAPES[shape_name or "decode_32k"]
    arch = cloud_accelerator()
    names = [m + "_smoke" if smoke else m for m in models]

    streams, recon_rows = [], {}
    for name in names:
        cfg = get_config(name)
        s = build_opstream(cfg, shape)
        r = reconcile_model_flops(s, cfg)
        lo, hi = RECONCILE_BAND
        ok = lo <= r["ratio"] <= hi
        if not ok:
            print(f"[model] WARNING: {name} stream/MODEL_FLOPS ratio "
                  f"{r['ratio']:.3f} outside [{lo}, {hi}]")
        recon_rows[cfg.name] = {"ratio": r["ratio"], "in_band": ok}
        streams.append(s)

    tasks, index = stream_sweep_tasks(streams, arch)
    store = ResultStore(store_dir) if store_dir else None
    t0 = time.time()
    res = union_opt_sweep(
        tasks, engine_backend=backend, engine_workers=0,
        result_store=store, **(sweep_kw or {}),
    )
    sweep_s = time.time() - t0
    stats = res.stats

    # measured collective term per model, when a dryrun artifact exists
    coll_s, art_recon = {}, {}
    for s in streams:
        base_model = s.model[:-len("_smoke")] if s.model.endswith("_smoke") else s.model
        p = artifact_path(base_model, s.shape, art_dir=art_dir)
        if not p.exists():
            continue
        art = json.loads(p.read_text())
        coll_s[s.model] = measured_collective_s(art)
        art_recon[s.model] = reconcile_with_artifact(s, art)
    if not coll_s:
        print(f"[model] no dryrun artifacts under {art_dir} for shape "
              f"{shape.name}; collective term = 0 (modeled compute only)")

    costs = aggregate_stream_costs(streams, index, res.solutions, arch,
                                   collective_s=coll_s)
    rows = []
    for s, c in zip(streams, costs):
        row = c.row()
        row.update({
            "kind": s.kind,
            "tokens_per_step": s.meta["tokens_per_step"],
            "n_ops_pre_dedup": s.meta["n_ops_pre_dedup"],
            "stream_flops": s.total_flops(),
            "reconcile": recon_rows[s.model],
        })
        if s.model in art_recon:
            row["artifact_reconcile"] = art_recon[s.model]
        rows.append(row)
        print(f"[model] {s.model:28s} {shape.name:14s} "
              f"ops {row['n_ops_pre_dedup']:4.0f} -> {row['n_unique_ops']:3d} uniq | "
              f"lat {c.latency_s:.3e}s en {c.energy_j:.3e}J "
              f"edp {c.edp:.3e} | flops-ratio {recon_rows[s.model]['ratio']:.3f}")
    print(f"[model] ONE sweep: {len(tasks)} tasks -> {stats['engines']} engine "
          f"groups, cache_hits {stats.get('cache_hits', 0)}, "
          f"store_hits {stats.get('store_hits', 0)}, "
          f"{stats.get('evals_per_s', 0):,.0f} evals/s ({sweep_s:.1f}s)")

    result = {
        "figure": "model",
        "smoke": smoke,
        "shape": shape.name,
        "backend": backend,
        "models": [s.model for s in streams],
        "rows": rows,
        "sweep_stats": {k: v for k, v in stats.items() if k != "group_wall"},
        "sweep_seconds": round(sweep_s, 3),
    }
    if store is not None:
        store.flush()
        if not deterministic_stats():
            result["result_store"] = store.stats_dict()
            print(f"[model] result store: {result['result_store']}")
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "model.json").write_text(json.dumps(result, indent=1))

    summary = {
        "smoke": smoke,
        "shape": shape.name,
        "evals_per_s": {backend: round(stats.get("evals_per_s", 0.0))},
        "edp": {f"{backend}/{r['model']}": r["edp"] for r in rows},
        "store_hits": {backend: stats.get("store_hits", 0)},
    }
    use_executor = bool((sweep_kw or {}).get("group_timeout_s")
                        or (sweep_kw or {}).get("journal"))
    if use_executor:
        print("[model] regression gate skipped: executor rows are not "
              "comparable to the direct-call baseline")
    elif smoke and regress_check and store is None and not update_baseline:
        check_regression(summary, ROOT_BENCH, regress_margin)
    elif smoke and store is not None:
        print("[model] regression gate skipped: warm-store rows are not "
              "comparable to the cold baseline")
    if update_baseline and store is None and not use_executor:
        ROOT_BENCH.write_text(json.dumps(summary, indent=1))
        print(f"[model] baseline rewritten at {ROOT_BENCH}")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced (_smoke) configs on a small shape")
    ap.add_argument("--models", default=",".join(MODELS),
                    help="comma list of model config names")
    ap.add_argument("--shape", default=None,
                    help="shape cell name (default: smoke shape / decode_32k)")
    ap.add_argument("--backend", default="numpy",
                    help="evaluation-engine miss-batch backend")
    ap.add_argument("--store", default=None, metavar="DIR",
                    help="persistent cross-run ResultStore directory")
    ap.add_argument("--art-dir", default="experiments/dryrun",
                    help="dryrun artifact directory for the measured "
                         "collective term")
    ap.add_argument("--no-regress-check", action="store_true",
                    help="skip the smoke-mode evals/s gate vs BENCH_model.json")
    ap.add_argument("--regress-margin", type=float, default=0.5,
                    help="fail when evals/s drops below this fraction of "
                         "the committed baseline (smoke mode only)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite BENCH_model.json from this run")
    add_sweep_args(ap)
    args = ap.parse_args()
    run(smoke=args.smoke,
        models=[m.strip() for m in args.models.split(",") if m.strip()],
        shape_name=args.shape, backend=args.backend,
        store_dir=args.store, regress_check=not args.no_regress_check,
        regress_margin=args.regress_margin,
        update_baseline=args.update_baseline,
        sweep_kw=sweep_kwargs(args), art_dir=args.art_dir)
