"""Run every benchmark: one per paper table/figure + the roofline reader.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig8 fig11  # subset
"""

from __future__ import annotations

import json
import sys
import time
from pathlib import Path

from benchmarks import (
    fig3_mapping_edp,
    fig8_ttgt,
    fig10_aspect_ratio,
    fig11_chiplet,
    mappers_bench,
    perf_variants,
    roofline,
)

BENCHES = {
    "fig3": fig3_mapping_edp.run,
    "fig8": fig8_ttgt.run,
    "fig10": fig10_aspect_ratio.run,
    "fig11": fig11_chiplet.run,
    "mappers": mappers_bench.run,
    "roofline": roofline.run,
    "perf_variants": perf_variants.run,
}


def main() -> None:
    names = sys.argv[1:] or list(BENCHES)
    summary = {}
    for name in names:
        print(f"\n===== {name} =====", flush=True)
        t0 = time.time()
        try:
            BENCHES[name]()
            summary[name] = {"ok": True, "seconds": round(time.time() - t0, 1)}
        except Exception as e:  # noqa: BLE001
            import traceback

            traceback.print_exc()
            summary[name] = {"ok": False, "error": f"{type(e).__name__}: {e}"}
    out = Path("experiments/benchmarks")
    out.mkdir(parents=True, exist_ok=True)
    (out / "summary.json").write_text(json.dumps(summary, indent=1))
    print("\n===== summary =====")
    for k, v in summary.items():
        print(f"  {k:10s} {'OK' if v['ok'] else 'FAIL'} "
              f"({v.get('seconds', '-')}s)")
    if not all(v["ok"] for v in summary.values()):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
