"""Mapper comparison (paper Sec. III-B1): search quality vs evaluations
for every mapper on the same problem/arch/cost-model -- the plug-and-play
matrix prior frameworks cannot run (each mapper was tied to one model).

Since the EvaluationEngine landed, every row also reports map-space search
THROUGHPUT: candidates/second (scored + bound-pruned), cache-hit rate and
pruned counts, so the engine's speedup stays tracked. Output goes to
``experiments/benchmarks/mappers.json`` (full rows) and ``BENCH_mappers.json``
at the repo root (the CI-tracked throughput summary).

Usage:
    python benchmarks/mappers_bench.py [--smoke] [--repeats N] [--workers W]

``--smoke`` runs a reduced matrix (one cost model, smaller budgets) that
finishes in a few seconds -- used by CI to track the perf trajectory.
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

from benchmarks.workloads import dnn_layers
from repro.core.architecture import cloud_accelerator
from repro.core.optimizer import union_opt

OUT = Path("experiments/benchmarks")
ROOT_BENCH = Path("BENCH_mappers.json")
MAPPERS = ["exhaustive", "random", "decoupled", "genetic", "heuristic"]
COST_MODELS = ["timeloop", "maestro"]

# Seed-revision throughput (evaluations/sec from the pre-engine bench on
# the reference container, best of 4 runs) -- kept here so every bench run
# reports the speedup trend against the same origin.
SEED_EVALS_PER_S = {
    ("timeloop", "exhaustive"): 2598,
    ("timeloop", "random"): 3002,
    ("timeloop", "decoupled"): 687,
    ("timeloop", "genetic"): 2742,
    ("timeloop", "heuristic"): 3247,
    ("maestro", "exhaustive"): 3017,
    ("maestro", "random"): 3071,
    ("maestro", "decoupled"): 851,
    ("maestro", "genetic"): 2830,
    ("maestro", "heuristic"): 3130,
}


def run(smoke: bool = False, repeats: int = 5, workers: int = 0,
        backend: str = "numpy") -> dict:
    problem = dnn_layers()["BERT-2"]
    arch = cloud_accelerator()
    cost_models = COST_MODELS[:1] if smoke else COST_MODELS
    mappers = ["random", "exhaustive", "genetic"] if smoke else MAPPERS
    rows = []
    for cm in cost_models:
        for mp in mappers:
            kw = {}
            if mp == "exhaustive":
                kw["max_mappings"] = 3000
            if smoke:
                if mp == "random":
                    kw["samples"] = 800
                if mp == "genetic":
                    kw["generations"] = 8
                if mp == "exhaustive":
                    kw["max_mappings"] = 1500
            best_s = float("inf")
            sol = None
            for _ in range(max(1, repeats)):
                t0 = time.time()
                sol = union_opt(
                    problem, arch, mapper=mp, cost_model=cm, metric="edp",
                    engine_workers=workers, engine_backend=backend, **kw,
                )
                best_s = min(best_s, time.time() - t0)
            res = sol.search
            candidates = res.evaluated + res.pruned
            evals_per_s = candidates / best_s
            seen = res.analyzed + res.cache_hits
            row = {
                "mapper": mp, "cost_model": cm,
                "edp": sol.cost.edp, "util": sol.cost.utilization,
                "evaluated": res.evaluated,
                "analyzed": res.analyzed,
                "cache_hits": res.cache_hits,
                "pruned": res.pruned,
                "candidates": candidates,
                "cache_hit_rate": res.cache_hits / seen if seen else 0.0,
                "seconds": best_s,
                "evals_per_s": evals_per_s,
                "speedup_vs_seed": (
                    evals_per_s / SEED_EVALS_PER_S[(cm, mp)]
                    if (cm, mp) in SEED_EVALS_PER_S and not smoke
                    else None
                ),
            }
            rows.append(row)
            print(
                f"[mappers] {cm:9s} x {mp:10s}: EDP {sol.cost.edp:.3e} "
                f"util {sol.cost.utilization:5.0%} "
                f"({candidates} cand, {best_s:.2f}s, {evals_per_s:,.0f} evals/s, "
                f"hit {row['cache_hit_rate']:.0%}, pruned {res.pruned})"
            )
    result = {
        "figure": "mappers",
        "problem": "BERT-2",
        "smoke": smoke,
        "engine_workers": workers,
        "engine_backend": backend,
        "rows": rows,
    }
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "mappers.json").write_text(json.dumps(result, indent=1))
    summary = {
        "problem": "BERT-2",
        "smoke": smoke,
        "engine_backend": backend,
        "evals_per_s": {f"{r['cost_model']}/{r['mapper']}": round(r["evals_per_s"]) for r in rows},
        "cache_hit_rate": {f"{r['cost_model']}/{r['mapper']}": round(r["cache_hit_rate"], 3) for r in rows},
        "pruned": {f"{r['cost_model']}/{r['mapper']}": r["pruned"] for r in rows},
        "speedup_vs_seed": {
            f"{r['cost_model']}/{r['mapper']}": round(r["speedup_vs_seed"], 2)
            for r in rows
            if r["speedup_vs_seed"] is not None
        },
    }
    ROOT_BENCH.write_text(json.dumps(summary, indent=1))
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="reduced CI matrix")
    ap.add_argument("--repeats", type=int, default=5, help="take best-of-N per row")
    ap.add_argument("--workers", type=int, default=0, help="engine process-pool size")
    ap.add_argument("--backend", default="numpy", choices=["numpy", "jax", "none"],
                    help="vectorized miss-batch backend (none = scalar path)")
    args = ap.parse_args()
    run(smoke=args.smoke, repeats=args.repeats, workers=args.workers,
        backend=args.backend)
