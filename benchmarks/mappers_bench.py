"""Mapper comparison (paper Sec. III-B1): search quality vs evaluations
for every mapper on the same problem/arch/cost-model -- the plug-and-play
matrix prior frameworks cannot run (each mapper was tied to one model)."""

from __future__ import annotations

import json
import time
from pathlib import Path

from benchmarks.workloads import dnn_layers
from repro.core.architecture import cloud_accelerator
from repro.core.optimizer import union_opt

OUT = Path("experiments/benchmarks")
MAPPERS = ["exhaustive", "random", "decoupled", "genetic", "heuristic"]
COST_MODELS = ["timeloop", "maestro"]


def run() -> dict:
    problem = dnn_layers()["BERT-2"]
    arch = cloud_accelerator()
    rows = []
    for cm in COST_MODELS:
        for mp in MAPPERS:
            kw = {"max_mappings": 3000} if mp == "exhaustive" else {}
            t0 = time.time()
            sol = union_opt(problem, arch, mapper=mp, cost_model=cm,
                            metric="edp", **kw)
            rows.append({
                "mapper": mp, "cost_model": cm,
                "edp": sol.cost.edp, "util": sol.cost.utilization,
                "evaluated": sol.search.evaluated,
                "seconds": time.time() - t0,
            })
            print(f"[mappers] {cm:9s} x {mp:10s}: EDP {sol.cost.edp:.3e} "
                  f"util {sol.cost.utilization:5.0%} "
                  f"({sol.search.evaluated} evals, {rows[-1]['seconds']:.1f}s)")
    result = {"figure": "mappers", "problem": "BERT-2", "rows": rows}
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "mappers.json").write_text(json.dumps(result, indent=1))
    return result


if __name__ == "__main__":
    run()
