"""Mapper comparison (paper Sec. III-B1): search quality vs evaluations
for every mapper on the same problem/arch/cost-model -- the plug-and-play
matrix prior frameworks cannot run (each mapper was tied to one model).

Since the EvaluationEngine landed, every row also reports map-space search
THROUGHPUT: candidates/second (scored + bound-pruned), cache-hit rate and
pruned counts, so the engine's speedup stays tracked. Output goes to
``experiments/benchmarks/mappers.json`` (full rows) and ``BENCH_mappers.json``
at the repo root (the CI-tracked throughput summary).

Usage:
    python benchmarks/mappers_bench.py [--smoke] [--repeats N] [--workers W]
                                       [--backend numpy,jax] [--store DIR]
                                       [--no-regress-check]
                                       [--group-timeout SECS] [--group-retries N]
                                       [--journal FILE] [--resume]

``--group-timeout``/``--journal``/``--resume`` route every row through the
fault-tolerant sweep executor (watchdogged dispatch, crash-safe journal,
``docs/sweep_service.md``); those runs are robustness drills and skip the
evals/s gate -- journal replays finish in microseconds and watchdogged
dispatch adds per-group overhead, so their timings are incomparable to
the committed cold floors.

``--backend`` takes a comma list; each backend runs the whole mapper
matrix and its rows are keyed ``backend/cost_model/mapper`` in the
summary, so the committed ``BENCH_mappers.json`` gates EVERY benchmarked
backend's evals/s (CI runs ``numpy,jax``).

``--smoke`` runs a reduced matrix (one cost model, smaller budgets, now
including ``heuristic`` so the batched/fused climb stays tracked) that
finishes in a few seconds -- used by CI to track the perf trajectory. In
smoke mode the run ASSERTS that evals/s has not regressed against the
committed ``BENCH_mappers.json`` (within ``--regress-margin``, default
50%, absorbing container noise) and fails with a per-row margin message
otherwise; ``--no-regress-check`` disables the gate. First runs bootstrap
instead of failing: a missing baseline file is recorded from the run, and
rows for a mapper/backend benchmarked for the first time are warned about
and appended without touching existing rows. Beyond that, the committed
``BENCH_mappers.json`` is only rewritten deliberately: smoke runs never
replace existing rows (a merely-passing run must not ratchet the floor
downward), full runs refuse to clobber a committed smoke baseline (the
gate would skip forever on a matrix mismatch), and warm-store rows are
never written (incomparable to cold runs) -- pass ``--update-baseline``
on a cold run to regenerate it.

Throughput rows report ``evals_per_s`` over the warm/cold-invariant
``considered`` total minus store-served candidates (see
``SearchResult.evals_per_s``); cold runs are unaffected.

``--store DIR`` shares one persistent :class:`ResultStore` across every
search and repeat (and across invocations): repeats stop re-scoring
identical signatures, and the summary reports the store counters. NOTE:
store hits bypass the admission filter, so evals/s rows measured with a
warm store are not comparable to the cold baseline -- the regression gate
refuses to run with ``--store``.
"""

from __future__ import annotations

import argparse
import json
import os
import time
from pathlib import Path

from benchmarks.workloads import dnn_layers
from repro.core.architecture import cloud_accelerator
from repro.core.cost import ResultStore
from repro.core.optimizer import SweepTask, union_opt, union_opt_sweep

OUT = Path("experiments/benchmarks")
ROOT_BENCH = Path("BENCH_mappers.json")
MAPPERS = ["exhaustive", "random", "decoupled", "genetic", "heuristic"]
COST_MODELS = ["timeloop", "maestro"]

# Seed-revision throughput (evaluations/sec from the pre-engine bench on
# the reference container, best of 4 runs) -- kept here so every bench run
# reports the speedup trend against the same origin.
SEED_EVALS_PER_S = {
    ("timeloop", "exhaustive"): 2598,
    ("timeloop", "random"): 3002,
    ("timeloop", "decoupled"): 687,
    ("timeloop", "genetic"): 2742,
    ("timeloop", "heuristic"): 3247,
    ("maestro", "exhaustive"): 3017,
    ("maestro", "random"): 3071,
    ("maestro", "decoupled"): 851,
    ("maestro", "genetic"): 2830,
    ("maestro", "heuristic"): 3130,
}


_SUMMARY_ROW_SECTIONS = (
    "evals_per_s", "cache_hit_rate", "pruned", "store_hits", "phase_s",
    "speedup_vs_seed", "n_traces", "device_syncs",
)


def record_baseline_rows(summary: dict, base: dict, new_keys, baseline_path: Path):
    """Merge first-run rows (new mapper/backend cells) into the committed
    baseline WITHOUT touching existing rows -- the bootstrap half of the
    warn-and-record contract. Returns the merged dict it wrote."""
    for section in _SUMMARY_ROW_SECTIONS:
        rows = summary.get(section, {})
        dst = base.setdefault(section, {})
        for key in new_keys:
            if key in rows:
                # setdefault, NOT assignment: a key can be "new" because
                # one section (say n_traces) lacks it while another
                # (evals_per_s) already has a committed floor -- existing
                # floors must never ratchet from a bootstrap merge
                dst.setdefault(key, rows[key])
    baseline_path.write_text(json.dumps(base, indent=1))
    return base


def check_regression(summary: dict, baseline_path: Path, margin: float) -> None:
    """Fail (SystemExit) when any evals/s row regresses below ``margin`` x
    the committed baseline. Only rows present in both files are compared
    (rows carry their backend in the key, so a jax row never gates a
    numpy row), and only when both were produced by the same (smoke)
    matrix.

    First-run and new-row cases bootstrap cleanly (warn-and-record, never
    crash or false-fail): a MISSING baseline file is written from this
    run's summary, and rows for a mapper/backend benchmarked for the
    first time are warned about and appended to the committed baseline --
    existing rows (the ratchet floor) are never overwritten."""
    if not baseline_path.exists():
        print(
            f"[mappers] no baseline at {baseline_path}; recording this run "
            "as the first baseline (no gate on a first run)"
        )
        baseline_path.write_text(json.dumps(summary, indent=1))
        return
    try:
        base = json.loads(baseline_path.read_text())
    except Exception as e:  # pragma: no cover - unreadable baseline
        print(f"[mappers] unreadable baseline ({e}); skipping regression gate")
        return
    if base.get("smoke") != summary["smoke"]:
        print("[mappers] baseline matrix differs (smoke); skipping gate")
        return
    failures = []
    new_keys = []
    for key, new_v in summary["evals_per_s"].items():
        old_v = base.get("evals_per_s", {}).get(key)
        if old_v is None:
            new_keys.append(key)
        elif old_v and new_v < old_v * margin:
            failures.append(
                f"  {key}: {new_v:,.0f} evals/s < {margin:.0%} of committed "
                f"{old_v:,.0f} (floor {old_v * margin:,.0f})"
            )
    if failures:
        raise SystemExit(
            "[mappers] evals/s REGRESSION vs committed BENCH_mappers.json "
            f"(margin {margin:.0%}):\n" + "\n".join(failures)
        )
    # Deterministic trace-count gate: a cold smoke row may trace AT MOST
    # as many compiled programs as the committed floor -- tracing is
    # counted (not timed), so this gate has no noise margin and catches
    # any O(sweep points) retrace regression (the shape-generic contract
    # is one program per shape class x model x metric x pow2 bucket).
    trace_failures = []
    for key, new_v in summary.get("n_traces", {}).items():
        old_v = base.get("n_traces", {}).get(key)
        if old_v is None:
            if key not in new_keys:
                new_keys.append(key)  # bootstrap: warn-and-record below
        elif new_v > old_v:
            trace_failures.append(
                f"  {key}: traced {new_v} compiled programs > committed "
                f"floor {old_v}"
            )
    if trace_failures:
        raise SystemExit(
            "[mappers] TRACE-COUNT regression vs committed "
            "BENCH_mappers.json (exact gate, no margin):\n"
            + "\n".join(trace_failures)
        )
    print(f"[mappers] regression gate OK (margin {margin:.0%} vs {baseline_path})")
    if new_keys:
        print(
            f"[mappers] WARNING: no committed baseline row for {new_keys} "
            "(first run of this mapper/backend); recording these rows"
        )
        record_baseline_rows(summary, base, new_keys, baseline_path)


def run(smoke: bool = False, repeats: int = 5, workers: int = 0,
        backend: str = "numpy", store_dir: str | None = None,
        regress_check: bool = True, regress_margin: float = 0.5,
        update_baseline: bool = False, group_timeout_s: float | None = None,
        group_retries: int = 2, journal: str | None = None,
        resume: bool = False) -> dict:
    if os.environ.get("UNION_BENCH_DEVICE"):
        # Opt-in device-mode bench: measures the device-resident search
        # loops (mega-batch precompute, generation-resident GA) on an
        # accelerator. On CPU-only hosts the mode skips CLEANLY -- device
        # residency on the jax CPU backend measures nothing the default
        # jax rows don't already cover.
        try:
            import jax

            dev_backend = jax.default_backend()
        except Exception:
            dev_backend = None
        if dev_backend in (None, "cpu"):
            print(
                "[mappers] UNION_BENCH_DEVICE=1 but no accelerator "
                f"(jax default backend: {dev_backend}); skipping the "
                "device-mode bench cleanly"
            )
            return {"figure": "mappers", "skipped": "no accelerator backend"}
        backend = "jax"
        regress_check = False  # accelerator rows never gate CPU floors
        print(f"[mappers] device-mode bench on jax backend: {dev_backend}")

    problem = dnn_layers()["BERT-2"]
    arch = cloud_accelerator()
    # any fault-tolerance knob routes rows through the sweep executor
    # (per-group watchdog/retries/journal); the default path stays the
    # direct union_opt call whose timing the committed floors gate
    use_executor = group_timeout_s is not None or journal is not None
    # each row is its own sweep; after the first, open the shared journal
    # in resume mode so rows ACCUMULATE (a fresh sweep otherwise starts a
    # fresh journal) and a re-invocation with --resume can replay them all
    journal_seeded = False
    cost_models = COST_MODELS[:1] if smoke else COST_MODELS
    mappers = ["random", "exhaustive", "genetic", "heuristic"] if smoke else MAPPERS
    backends = [b.strip() for b in backend.split(",") if b.strip()]
    store = ResultStore(store_dir) if store_dir else None
    rows = []
    for be in backends:
        for cm in cost_models:
            for mp in mappers:
                kw = {}
                if mp == "exhaustive":
                    kw["max_mappings"] = 3000
                if smoke:
                    if mp == "random":
                        kw["samples"] = 800
                    if mp == "genetic":
                        kw["generations"] = 8
                    if mp == "exhaustive":
                        kw["max_mappings"] = 1500
                best_s = float("inf")
                sol = None
                # cold-run trace/sync counters: the FIRST repeat traces
                # (later repeats hit the process-wide program cache), so
                # the row records the max across repeats -- the
                # deterministic cold count the trace gate compares
                n_traces = 0
                device_syncs = 0
                for _ in range(max(1, repeats)):
                    t0 = time.time()
                    if use_executor:
                        sol = union_opt_sweep(
                            [SweepTask(problem, arch, mapper=mp,
                                       cost_model=cm, metric="edp",
                                       mapper_kw=kw)],
                            engine_workers=workers, engine_backend=be,
                            result_store=store,
                            group_timeout_s=group_timeout_s,
                            max_group_retries=group_retries,
                            journal=journal,
                            resume=resume or (journal is not None
                                              and journal_seeded),
                        )[0]
                        journal_seeded = True
                    else:
                        sol = union_opt(
                            problem, arch, mapper=mp, cost_model=cm, metric="edp",
                            engine_workers=workers, engine_backend=be,
                            result_store=store, **kw,
                        )
                    best_s = min(best_s, time.time() - t0)
                    n_traces = max(n_traces, sol.search.n_traces)
                    device_syncs = max(device_syncs, sol.search.device_syncs)
                res = sol.search
                candidates = res.evaluated + res.pruned
                # Throughput numerator = SearchResult.scored (warm/cold-
                # invariant submitted total minus store-served candidates;
                # cold runs stay comparable with historical numbers), over
                # the best-of-repeats wall clock.
                scored = res.scored
                evals_per_s = scored / best_s
                seen = res.analyzed + res.cache_hits + res.store_hits
                row = {
                    "mapper": mp, "cost_model": cm, "backend": be,
                    "edp": sol.cost.edp, "util": sol.cost.utilization,
                    "evaluated": res.evaluated,
                    "analyzed": res.analyzed,
                    "cache_hits": res.cache_hits,
                    "store_hits": res.store_hits,
                    "pruned": res.pruned,
                    "candidates": candidates,
                    "considered": res.considered,
                    "fused_dispatches": res.fused_dispatches,
                    "n_traces": n_traces,
                    "device_syncs": device_syncs,
                    "cache_hit_rate": res.cache_hits / seen if seen else 0.0,
                    "seconds": best_s,
                    "evals_per_s": evals_per_s,
                    # per-phase engine wall-clock of the LAST repeat:
                    # admission (bound stage) vs scoring (miss evaluation)
                    "admit_s": res.admit_s,
                    "score_s": res.score_s,
                    "speedup_vs_seed": (
                        evals_per_s / SEED_EVALS_PER_S[(cm, mp)]
                        if (cm, mp) in SEED_EVALS_PER_S
                        and not smoke and be == "numpy"
                        else None
                    ),
                }
                rows.append(row)
                print(
                    f"[mappers] {be:5s} {cm:9s} x {mp:10s}: "
                    f"EDP {sol.cost.edp:.3e} "
                    f"util {sol.cost.utilization:5.0%} "
                    f"({scored} scored, {best_s:.2f}s, "
                    f"{evals_per_s:,.0f} evals/s, "
                    f"hit {row['cache_hit_rate']:.0%}, pruned {res.pruned}, "
                    f"store {res.store_hits}, traces {n_traces}, "
                    f"syncs {device_syncs}, admit {res.admit_s*1e3:.1f}ms, "
                    f"score {res.score_s*1e3:.1f}ms)"
                )
    result = {
        "figure": "mappers",
        "problem": "BERT-2",
        "smoke": smoke,
        "engine_workers": workers,
        "engine_backends": backends,
        "rows": rows,
    }
    if store is not None:
        store.flush()
        result["result_store"] = store.stats_dict()
        print(f"[mappers] result store: {result['result_store']}")
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "mappers.json").write_text(json.dumps(result, indent=1))
    key_of = lambda r: f"{r['backend']}/{r['cost_model']}/{r['mapper']}"  # noqa: E731
    summary = {
        "problem": "BERT-2",
        "smoke": smoke,
        "engine_backends": backends,
        "evals_per_s": {key_of(r): round(r["evals_per_s"]) for r in rows},
        "cache_hit_rate": {key_of(r): round(r["cache_hit_rate"], 3) for r in rows},
        "pruned": {key_of(r): r["pruned"] for r in rows},
        "store_hits": {key_of(r): r["store_hits"] for r in rows},
        "phase_s": {
            key_of(r): {"admit": round(r["admit_s"], 4), "score": round(r["score_s"], 4)}
            for r in rows
        },
        "speedup_vs_seed": {
            key_of(r): round(r["speedup_vs_seed"], 2)
            for r in rows
            if r["speedup_vs_seed"] is not None
        },
        # deterministic cold trace counts (exact gate, see check_regression)
        # and device-loop sync points per row
        "n_traces": {key_of(r): r["n_traces"] for r in rows},
        "device_syncs": {key_of(r): r["device_syncs"] for r in rows},
    }
    if use_executor:
        # journal replays finish in microseconds and watchdogged dispatch
        # adds per-group overhead: rows are for robustness drills, not
        # comparable to the committed cold floors
        print("[mappers] regression gate skipped: executor rows "
              "(--group-timeout/--journal) are not comparable to the "
              "direct-call baseline")
    elif smoke and regress_check and store is None and not update_baseline:
        check_regression(summary, ROOT_BENCH, regress_margin)
    elif smoke and update_baseline:
        print("[mappers] regression gate skipped: --update-baseline is a "
              "deliberate baseline rewrite")
    elif smoke and store is not None:
        print("[mappers] regression gate skipped: warm-store rows are not "
              "comparable to the cold baseline")
    # Baseline rewrite rules: a merely-passing smoke run must not replace
    # the committed floor (the gate would ratchet downward), warm-store
    # rows must never become the baseline (incomparable to cold runs),
    # and a full-matrix run must not clobber a committed SMOKE baseline
    # (the gate would then skip forever on 'matrix differs'). Explicit
    # --update-baseline overrides the matrix guard, never the store one.
    write_baseline = store is None and update_baseline and not use_executor
    if store is None and not update_baseline and not smoke and not use_executor:
        try:
            write_baseline = not json.loads(ROOT_BENCH.read_text()).get("smoke", False)
        except Exception:
            write_baseline = True  # absent/unreadable baseline: establish one
    if write_baseline:
        ROOT_BENCH.write_text(json.dumps(summary, indent=1))
    elif store is not None and update_baseline:
        print("[mappers] baseline NOT updated: warm-store rows are not a "
              "valid cold baseline")
    elif not smoke and not update_baseline:
        print(f"[mappers] baseline untouched ({ROOT_BENCH} is a smoke "
              "baseline; pass --update-baseline to replace it)")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="reduced CI matrix")
    ap.add_argument("--repeats", type=int, default=5, help="take best-of-N per row")
    ap.add_argument("--workers", type=int, default=0, help="engine process-pool size")
    ap.add_argument("--backend", default="numpy",
                    help="comma list of miss-batch backends to benchmark "
                         "(numpy, jax, none = scalar path); each backend "
                         "runs the whole matrix and gates its own "
                         "evals/s rows")
    ap.add_argument("--store", default=None, metavar="DIR",
                    help="persistent cross-search ResultStore directory")
    ap.add_argument("--no-regress-check", action="store_true",
                    help="skip the smoke-mode evals/s gate vs BENCH_mappers.json")
    ap.add_argument("--regress-margin", type=float, default=0.5,
                    help="fail when evals/s drops below this fraction of the "
                         "committed baseline (smoke mode only)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite BENCH_mappers.json from this (smoke) run; "
                         "without it, smoke runs leave the committed "
                         "baseline untouched")
    ap.add_argument("--group-timeout", type=float, default=None, metavar="SECS",
                    help="route rows through the fault-tolerant sweep "
                         "executor with this per-group deadline "
                         "(robustness drill; disables the evals/s gate)")
    ap.add_argument("--group-retries", type=int, default=2, metavar="N",
                    help="retry budget per group when the executor path "
                         "is active (default 2)")
    ap.add_argument("--journal", default=None, metavar="FILE",
                    help="sweep journal for the executor path; completed "
                         "rows survive a crash and --resume replays them")
    ap.add_argument("--resume", action="store_true",
                    help="replay rows already completed in --journal "
                         "instead of re-searching them")
    args = ap.parse_args()
    if args.resume and not args.journal:
        raise SystemExit("[mappers] --resume requires --journal FILE")
    run(smoke=args.smoke, repeats=args.repeats, workers=args.workers,
        backend=args.backend, store_dir=args.store,
        regress_check=not args.no_regress_check,
        regress_margin=args.regress_margin,
        update_baseline=args.update_baseline,
        group_timeout_s=args.group_timeout, group_retries=args.group_retries,
        journal=args.journal, resume=args.resume)
