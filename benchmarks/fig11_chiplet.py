"""Fig. 11: chipletization -- EDP vs per-chiplet fill bandwidth.

16 chiplets x the edge config (4096 PEs, Simba-like); sweep the DRAM ->
chiplet-global-buffer bandwidth. Timeloop-like cost model (hierarchical).
Expectation: EDP drops steeply while fill-bandwidth-bound, then saturates;
layers with more reuse saturate earlier (ResNet earlier than DLRM/BERT).
"""

from __future__ import annotations

import json
from pathlib import Path

from benchmarks.workloads import dnn_layers
from repro.core.architecture import chiplet_accelerator
from repro.core.optimizer import union_opt

OUT = Path("experiments/benchmarks")
BWS = [0.125e9, 0.25e9, 0.5e9, 1e9, 2e9, 4e9, 6e9, 8e9, 12e9, 16e9, 32e9]


def run() -> dict:
    layers = dnn_layers()
    result = {"figure": "fig11", "bandwidths_gbps": [b / 1e9 for b in BWS], "rows": {}}
    for wname, problem in layers.items():
        edps = []
        searches = []
        for bw in BWS:
            arch = chiplet_accelerator(fill_bandwidth=bw)
            sol = union_opt(problem, arch, mapper="heuristic",
                            cost_model="timeloop", metric="edp")
            edps.append(sol.cost.edp)
            searches.append(sol.search.stats_dict())
        # saturation point: first bw within 5% of the best (highest-bw) EDP
        sat = next(
            (BWS[i] for i in range(len(BWS)) if edps[i] <= edps[-1] * 1.05),
            BWS[-1],
        )
        result["rows"][wname] = {
            "edp": edps,
            "saturation_bw_gbps": sat / 1e9,
            "search": searches,
        }
        print(f"[fig11] {wname:10s} EDP x{edps[0]/edps[-1]:7.1f} drop over sweep; "
              f"saturates at ~{sat/1e9:g} GB/s")
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "fig11.json").write_text(json.dumps(result, indent=1))
    return result


if __name__ == "__main__":
    run()
