"""Fig. 11: chipletization -- EDP vs per-chiplet fill bandwidth.

16 chiplets x the edge config (4096 PEs, Simba-like); sweep the DRAM ->
chiplet-global-buffer bandwidth. Timeloop-like cost model (hierarchical).
Expectation: EDP drops steeply while fill-bandwidth-bound, then saturates;
layers with more reuse saturate earlier (ResNet earlier than DLRM/BERT).
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from benchmarks.sweep_cli import add_sweep_args, deterministic_stats, sweep_kwargs
from benchmarks.workloads import dnn_layers
from repro.core.architecture import chiplet_accelerator
from repro.core.cost import ResultStore
from repro.core.optimizer import SweepTask, union_opt_sweep

OUT = Path("experiments/benchmarks")
BWS = [0.125e9, 0.25e9, 0.5e9, 1e9, 2e9, 4e9, 6e9, 8e9, 12e9, 16e9, 32e9]


def run(store_dir: str | None = None, store_cap: int | None = None,
        backend: str = "numpy", sweep_kw: dict | None = None) -> dict:
    """One ``union_opt_sweep`` over every (workload, bandwidth) point:
    shared store, content-aliased contexts, per-space bucketed warmup
    under ``--backend jax``."""
    layers = dnn_layers()
    store = (
        ResultStore(store_dir, max_entries_per_space=store_cap)
        if store_dir
        else None
    )
    tasks = [
        SweepTask(problem, chiplet_accelerator(fill_bandwidth=bw),
                  mapper="heuristic", cost_model="timeloop", metric="edp",
                  tag=(wname, bw))
        for wname, problem in layers.items()
        for bw in BWS
    ]
    sweep = union_opt_sweep(tasks, engine_backend=backend, result_store=store,
                            **(sweep_kw or {}))
    sols = {t.tag: s for t, s in zip(tasks, sweep)}
    result = {
        "figure": "fig11",
        "bandwidths_gbps": [b / 1e9 for b in BWS],
        "rows": {},
        "sweep": sweep.stats,
    }
    for wname, problem in layers.items():
        edps = []
        searches = []
        for bw in BWS:
            sol = sols[(wname, bw)]
            edps.append(sol.cost.edp)
            searches.append(sol.search.stats_dict())
        # saturation point: first bw within 5% of the best (highest-bw) EDP
        sat = next(
            (BWS[i] for i in range(len(BWS)) if edps[i] <= edps[-1] * 1.05),
            BWS[-1],
        )
        result["rows"][wname] = {
            "edp": edps,
            "saturation_bw_gbps": sat / 1e9,
            "search": searches,
        }
        print(f"[fig11] {wname:10s} EDP x{edps[0]/edps[-1]:7.1f} drop over sweep; "
              f"saturates at ~{sat/1e9:g} GB/s")
    if store is not None:
        store.flush()
        if not deterministic_stats():  # hit counts shift with store warmth
            result["result_store"] = store.stats_dict()
            print(f"[fig11] result store: {result['result_store']}")
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "fig11.json").write_text(json.dumps(result, indent=1))
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--store", default=None, metavar="DIR",
                    help="persistent cross-search ResultStore directory")
    ap.add_argument("--store-cap", type=int, default=None, metavar="N",
                    help="per-space LRU entry cap for the result store "
                         "(disk tier compacted at flush; default unbounded)")
    ap.add_argument("--backend", default="numpy",
                    choices=["numpy", "jax", "none"],
                    help="evaluation-engine array backend for the sweep")
    add_sweep_args(ap)
    args = ap.parse_args()
    run(store_dir=args.store, store_cap=args.store_cap, backend=args.backend,
        sweep_kw=sweep_kwargs(args))
