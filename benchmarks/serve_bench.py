#!/usr/bin/env python
"""Load generator + CI gate for the mapping-as-a-service daemon.

Drives a REAL daemon subprocess (``python -m repro.serve.mapping_service``)
through three phases and emits ``BENCH_serve.json`` rows under the same
warn-and-record bootstrap contract as ``mappers_bench``:

1. **Poisson load** -- deterministic Poisson arrivals (seeded
   ``random.Random``) over a warm/cold query mix: each distinct shape is
   cold exactly once, every repeat must be served from the answer journal.
   Gates: warm-hit accounting is EXACT (``store_hits == requests -
   distinct shapes``, deterministic with a sequential client); p50/p99
   latency and warm-path latency are recorded, never gated (wall-clock on
   shared runners is noise).
2. **Backpressure burst** -- a concurrent burst of distinct cold queries
   against a small admission queue; at least one request MUST be shed
   with HTTP 429 + Retry-After (the bounded-queue contract), and every
   burst response must be a well-formed envelope or a 429.
3. **Circuit-breaker drill** (``--breaker-drill``, CI default) -- a
   second daemon with ``--backend jax`` and injected
   ``jaxfail:0;jaxfail:1``: the breaker must walk closed -> open ->
   half-open -> closed within the drill's query stream, asserted from
   ``/metrics``.

Usage: ``PYTHONPATH=src:. python benchmarks/serve_bench.py --smoke``.
"""

from __future__ import annotations

import argparse
import concurrent.futures as cf
import json
import os
import random
import signal
import statistics
import subprocess
import sys
import tempfile
import time
import urllib.error
import urllib.request
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
BENCH_PATH = REPO / "BENCH_serve.json"


# --------------------------------------------------------------------- #
# Daemon harness
# --------------------------------------------------------------------- #
def _env() -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [str(REPO / "src"), str(REPO), env.get("PYTHONPATH", "")]
    ).rstrip(os.pathsep)
    return env


def start_daemon(state_dir: str, *, backend: str = "numpy",
                 deadline_s: float = 20.0, queue_cap: int = 8,
                 workers: int = 2, fault_spec: str | None = None,
                 timeout_s: float = 60.0):
    """Spawn the daemon, wait for its ready file, return (proc, port)."""
    ready = os.path.join(state_dir, "ready.json")
    cmd = [
        sys.executable, "-m", "repro.serve.mapping_service",
        "--state-dir", state_dir, "--ready-file", ready,
        "--backend", backend, "--deadline-s", str(deadline_s),
        "--queue-cap", str(queue_cap), "--workers", str(workers),
    ]
    if fault_spec:
        cmd += ["--fault-spec", fault_spec]
    proc = subprocess.Popen(cmd, env=_env())
    t0 = time.monotonic()
    while time.monotonic() - t0 < timeout_s:
        if os.path.exists(ready):
            with open(ready) as f:
                port = json.load(f)["port"]
            return proc, port
        if proc.poll() is not None:
            raise SystemExit(f"daemon died at startup (rc={proc.returncode})")
        time.sleep(0.05)
    proc.kill()
    raise SystemExit("daemon did not become ready in time")


def stop_daemon(proc: subprocess.Popen, timeout_s: float = 30.0) -> int:
    proc.send_signal(signal.SIGTERM)
    try:
        return proc.wait(timeout=timeout_s)
    except subprocess.TimeoutExpired:
        proc.kill()
        return proc.wait()


def post(port: int, payload: dict, timeout: float = 120.0):
    """POST /v1/mapping; returns (status, envelope)."""
    req = urllib.request.Request(
        f"http://127.0.0.1:{port}/v1/mapping",
        data=json.dumps(payload).encode(),
        headers={"Content-Type": "application/json"},
    )
    try:
        with urllib.request.urlopen(req, timeout=timeout) as r:
            return r.status, json.loads(r.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def get(port: int, path: str, timeout: float = 30.0) -> dict:
    with urllib.request.urlopen(
        f"http://127.0.0.1:{port}{path}", timeout=timeout
    ) as r:
        return json.loads(r.read())


def gemm_query(m: int, n: int, k: int, budget: int = 150,
               deadline_s: float | None = None) -> dict:
    q = {
        "problem": {"kind": "gemm", "m": m, "n": n, "k": k},
        "arch": {"kind": "edge", "aspect": [16, 16]},
        "metric": "edp",
        "mapper": {"name": "random", "kw": {"seed": 7}},
        "budget": budget,
    }
    if deadline_s is not None:
        q["deadline_s"] = deadline_s
    return q


# --------------------------------------------------------------------- #
# Phase 1: Poisson warm/cold mix
# --------------------------------------------------------------------- #
def poisson_phase(port: int, *, requests: int, rate_per_s: float,
                  shapes: int, seed: int) -> dict:
    rng = random.Random(seed)
    sizes = [32 + 16 * i for i in range(shapes)]
    latencies, warm_latencies = [], []
    cold_seen: set = set()
    for i in range(requests):
        time.sleep(rng.expovariate(rate_per_s))
        m = rng.choice(sizes)
        t0 = time.perf_counter()
        st, env = post(port, gemm_query(m, m, m))
        dt_ms = (time.perf_counter() - t0) * 1e3
        assert st == 200 and env["ok"], (st, env)
        assert not env["budget_exhausted"], env
        latencies.append(dt_ms)
        if env["source"] == "store":
            warm_latencies.append(dt_ms)
        else:
            cold_seen.add(m)
    qs = sorted(latencies)
    p = lambda q: qs[min(len(qs) - 1, int(q * len(qs)))]  # noqa: E731
    return {
        "requests": requests,
        "distinct_shapes": shapes,
        "cold": len(cold_seen),
        "warm": len(warm_latencies),
        "p50_ms": round(statistics.median(latencies), 3),
        "p99_ms": round(p(0.99), 3),
        "warm_p50_ms": round(statistics.median(warm_latencies), 3)
        if warm_latencies else None,
    }


# --------------------------------------------------------------------- #
# Phase 2: backpressure burst
# --------------------------------------------------------------------- #
def burst_phase(port: int, *, burst: int) -> dict:
    """Concurrent distinct COLD queries (cold searches serialize on the
    daemon's search lock, so workers stay busy) against the bounded
    queue; count 429s."""
    def one(i: int):
        m = 40 + 8 * i  # distinct shapes: all cold, nothing journal-served
        return post(port, gemm_query(m, m + 8, m, budget=400, deadline_s=5.0))

    with cf.ThreadPoolExecutor(max_workers=burst) as ex:
        out = list(ex.map(one, range(burst)))
    shed = sum(1 for st, _env in out if st == 429)
    served = sum(1 for st, env in out if st == 200 and env.get("ok"))
    assert shed + served == burst, out
    return {"burst": burst, "shed": shed, "served": served}


# --------------------------------------------------------------------- #
# Phase 3: circuit-breaker drill
# --------------------------------------------------------------------- #
def breaker_phase(state_dir: str) -> dict:
    proc, port = start_daemon(
        state_dir, backend="jax", deadline_s=60.0, workers=1,
        fault_spec="jaxfail:0;jaxfail:1",
    )
    try:
        for i in range(4):
            m = 32 + 16 * i
            st, env = post(port, gemm_query(m, 32, 32, budget=120))
            assert st == 200 and env["ok"], (st, env)
        metrics = get(port, "/metrics")
    finally:
        stop_daemon(proc)
    br = metrics["breaker"]
    for leg in ("closed->open", "open->half_open", "half_open->closed"):
        assert leg in br["transitions"], br
    assert br["state"] == "closed", br
    return {
        "transitions": br["transitions"],
        "opened": br["opened"],
        "recovered": br["recovered"],
        "final_state": br["state"],
    }


# --------------------------------------------------------------------- #
# Warn-and-record bootstrap gate (mappers_bench contract)
# --------------------------------------------------------------------- #
def record_rows(summary: dict, baseline_path: Path) -> None:
    """Latency/robustness rows bootstrap warn-and-record: a missing
    baseline is written whole; new keys are warned about and appended
    with ``setdefault`` (existing rows are never overwritten). The
    DETERMINISTIC contracts (exact warm-hit accounting, shed >= 1,
    breaker recovery) are asserted inline by the phases above, not
    ratcheted here -- wall-clock latencies on shared runners are
    recorded for trend-watching only."""
    if not baseline_path.exists():
        print(f"[serve] no baseline at {baseline_path}; recording this run")
        baseline_path.write_text(json.dumps(summary, indent=1))
        return
    try:
        base = json.loads(baseline_path.read_text())
    except Exception as e:
        print(f"[serve] unreadable baseline ({e}); rewriting")
        baseline_path.write_text(json.dumps(summary, indent=1))
        return
    changed = False
    for section, rows in summary.items():
        if not isinstance(rows, dict):
            base.setdefault(section, rows)
            continue
        dst = base.setdefault(section, {})
        for key, val in rows.items():
            if key not in dst:
                print(f"[serve] new row {section}.{key}; recording")
                dst.setdefault(key, val)
                changed = True
    if changed:
        baseline_path.write_text(json.dumps(base, indent=1))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CI matrix (fewer requests, small burst)")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--rate", type=float, default=25.0,
                    help="Poisson arrival rate (requests/s)")
    ap.add_argument("--shapes", type=int, default=None)
    ap.add_argument("--burst", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-breaker-drill", dest="breaker_drill",
                    action="store_false", default=True)
    args = ap.parse_args(argv)

    requests = args.requests or (24 if args.smoke else 120)
    shapes = args.shapes or (4 if args.smoke else 8)
    burst = args.burst or (8 if args.smoke else 16)

    summary = {"smoke": bool(args.smoke), "seed": args.seed}
    with tempfile.TemporaryDirectory(prefix="serve-bench-") as td:
        proc, port = start_daemon(
            td, backend="numpy", deadline_s=30.0, queue_cap=2, workers=2
        )
        try:
            summary["poisson"] = poisson_phase(
                port, requests=requests, rate_per_s=args.rate,
                shapes=shapes, seed=args.seed,
            )
            summary["burst"] = burst_phase(port, burst=burst)
            metrics = get(port, "/metrics")
        finally:
            rc = stop_daemon(proc)
        assert rc == 0, f"daemon drain exit code {rc}"

        # warm-hit accounting is EXACT: every repeat of an answered shape
        # must be journal-served with zero re-search
        pz = summary["poisson"]
        expected_warm = pz["requests"] - pz["cold"]
        assert pz["warm"] == expected_warm, (pz, metrics)
        assert metrics["shed"] == summary["burst"]["shed"], metrics
        assert summary["burst"]["shed"] >= 1, (
            "backpressure never fired -- queue bound is not enforced",
            summary["burst"],
        )
        summary["service_metrics"] = {
            k: metrics[k]
            for k in ("queries", "store_hits", "searches", "partials",
                      "shed", "seeded", "seed_misfires", "neighbor_hits")
        }

    if args.breaker_drill:
        with tempfile.TemporaryDirectory(prefix="serve-breaker-") as td:
            summary["breaker"] = breaker_phase(td)

    record_rows(summary, BENCH_PATH)
    print(json.dumps(summary, indent=1))
    print("[serve] all phase contracts held")
    return 0


if __name__ == "__main__":
    sys.exit(main())
