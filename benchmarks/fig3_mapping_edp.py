"""Fig. 3: the mapping space of ONE layer spans orders of magnitude.

A DLRM layer on a 3-level spatial architecture with a 16x16 PE array:
sample mappings from the Union map-space, report normalized energy /
latency / EDP spread, and show the best mapping Union-opt finds.

The sample population keeps the historical v1 candidate stream (so the
reported spreads stay byte-comparable across releases) but is SCORED as
one engine batch -- the same vectorized array program the searches use,
bit-identical to per-candidate ``cm.evaluate`` -- and the search itself
runs through :func:`union_opt_sweep` (shared store flush, bucketed jax
warmup under ``--backend jax``).
"""

from __future__ import annotations

import argparse
import json
import random
from pathlib import Path

from benchmarks.sweep_cli import add_sweep_args, deterministic_stats, sweep_kwargs
from benchmarks.workloads import dnn_layers
from repro.core.architecture import edge_accelerator
from repro.core.cost import EvaluationEngine, ResultStore, TimeloopLikeModel
from repro.core.mapspace import MapSpace
from repro.core.optimizer import SweepTask, union_opt_sweep

OUT = Path("experiments/benchmarks")


def run(samples: int = 300, seed: int = 0, store_dir: str | None = None,
        store_cap: int | None = None, backend: str = "numpy",
        sweep_kw: dict | None = None) -> dict:
    problem = dnn_layers()["DLRM-1"]
    arch = edge_accelerator(aspect=(16, 16))
    cm = TimeloopLikeModel()
    space = MapSpace(problem, arch)
    rng = random.Random(seed)
    store = (
        ResultStore(store_dir, max_entries_per_space=store_cap)
        if store_dir
        else None
    )

    genomes = [space.random_genome(rng) for _ in range(samples)]
    with EvaluationEngine(
        cm, problem, arch, metric="edp", prune=False, backend=backend
    ) as engine:
        costs = engine.evaluate_batch(genomes)
    rows = [
        {"latency": c.latency_cycles, "energy": c.energy_pj,
         "edp": c.edp, "util": c.utilization}
        for c in costs
    ]
    sweep = union_opt_sweep(
        [SweepTask(problem, arch, mapper="heuristic", cost_model=cm,
                   metric="edp")],
        engine_backend=backend,
        result_store=store,
        **(sweep_kw or {}),
    )
    best = sweep[0]
    rows.sort(key=lambda r: r["edp"])
    e_min = min(r["energy"] for r in rows)
    l_min = min(r["latency"] for r in rows)
    result = {
        "figure": "fig3",
        "problem": "DLRM-1 (paper Fig. 3, 16x16 array)",
        "samples": samples,
        "edp_spread": rows[-1]["edp"] / rows[0]["edp"],
        "energy_spread": max(r["energy"] for r in rows) / e_min,
        "latency_spread": max(r["latency"] for r in rows) / l_min,
        "best_sampled_edp": rows[0]["edp"],
        "union_opt_edp": best.cost.edp,
        "union_opt_util": best.cost.utilization,
        "search": best.search.stats_dict(),
        "sweep": sweep.stats,
        "normalized": [
            {"energy": r["energy"] / e_min, "latency": r["latency"] / l_min}
            for r in rows[:: max(1, samples // 50)]
        ],
    }
    if store is not None:
        store.flush()
        if not deterministic_stats():  # hit counts shift with store warmth
            result["result_store"] = store.stats_dict()
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "fig3.json").write_text(json.dumps(result, indent=1))
    print(f"[fig3] DLRM-1 on 16x16: EDP spread x{result['edp_spread']:.1f} "
          f"(energy x{result['energy_spread']:.2f}, latency x{result['latency_spread']:.1f}) "
          f"over {samples} sampled mappings; union-opt EDP "
          f"{'<=' if best.cost.edp <= rows[0]['edp'] * 1.001 else '>'} best sample")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--samples", type=int, default=300,
                    help="sampled mappings (CI smoke uses a reduced count)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--store", default=None, metavar="DIR",
                    help="persistent cross-search ResultStore directory "
                         "(warm re-runs skip re-scoring identical signatures)")
    ap.add_argument("--store-cap", type=int, default=None, metavar="N",
                    help="per-space LRU entry cap for the result store "
                         "(disk tier compacted at flush; default unbounded)")
    ap.add_argument("--backend", default="numpy",
                    choices=["numpy", "jax", "none"],
                    help="evaluation-engine array backend for sampling and "
                         "search (jax = fused single-dispatch pipeline with "
                         "bucketed warmup)")
    add_sweep_args(ap)
    args = ap.parse_args()
    run(samples=args.samples, seed=args.seed, store_dir=args.store,
        store_cap=args.store_cap, backend=args.backend,
        sweep_kw=sweep_kwargs(args))
