"""Fig. 10: aspect-ratio exploration for flexible accelerators.

Edge (256 PEs) and cloud (2048 PEs) flexible arrays reconfigured to every
aspect ratio; per DNN workload the mapper finds the best mapping under the
MAESTRO-like cost model (the paper uses MAESTRO here because it models
configurable cluster sizes). Expectation: EDP saturates once utilization
is maximized; balanced ratios win most workloads but skewed GEMMs prefer
skewed arrays -- the motivation for cluster-target mappings.
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from benchmarks.sweep_cli import add_sweep_args, deterministic_stats, sweep_kwargs
from benchmarks.workloads import CLOUD_ASPECTS, EDGE_ASPECTS, dnn_layers
from repro.core.architecture import cloud_accelerator, edge_accelerator
from repro.core.cost import ResultStore
from repro.core.optimizer import SweepTask, union_opt_sweep

OUT = Path("experiments/benchmarks")


def run(store_dir: str | None = None, store_cap: int | None = None,
        backend: str = "numpy", sweep_kw: dict | None = None) -> dict:
    """The whole figure is ONE ``union_opt_sweep``: every
    (deployment, workload, aspect) point becomes a task, so the sweep
    shares the result store, aliases content-equal analysis contexts, and
    (under ``--backend jax``) pre-traces each space's fused runner once
    before its timed search."""
    layers = dnn_layers()
    store = (
        ResultStore(store_dir, max_entries_per_space=store_cap)
        if store_dir
        else None
    )
    tasks = []
    for tag, mk, aspects in (
        ("edge", edge_accelerator, EDGE_ASPECTS),
        ("cloud", cloud_accelerator, CLOUD_ASPECTS),
    ):
        for wname, problem in layers.items():
            for aspect in aspects:
                tasks.append(SweepTask(
                    problem, mk(aspect=aspect), mapper="heuristic",
                    cost_model="maestro", metric="edp",
                    tag=(tag, wname, "x".join(map(str, aspect))),
                ))
    sweep = union_opt_sweep(tasks, engine_backend=backend, result_store=store,
                            **(sweep_kw or {}))
    result = {"figure": "fig10", "edge": {}, "cloud": {}, "sweep": sweep.stats}
    for task, sol in zip(tasks, sweep):
        tag, wname, aspect = task.tag
        result[tag].setdefault(wname, {})[aspect] = {
            "edp": sol.cost.edp, "util": sol.cost.utilization,
            "search": sol.search.stats_dict(),
        }
    for tag in ("edge", "cloud"):
        for wname, row in result[tag].items():
            best = min(row, key=lambda k: row[k]["edp"])
            print(f"[fig10] {tag:5s} {wname:10s} best aspect {best:8s} "
                  f"(util {row[best]['util']:.0%})")
    if store is not None:
        store.flush()
        if not deterministic_stats():  # hit counts shift with store warmth
            result["result_store"] = store.stats_dict()
            print(f"[fig10] result store: {result['result_store']}")
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "fig10.json").write_text(json.dumps(result, indent=1))
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--store", default=None, metavar="DIR",
                    help="persistent cross-search ResultStore directory")
    ap.add_argument("--store-cap", type=int, default=None, metavar="N",
                    help="per-space LRU entry cap for the result store "
                         "(disk tier compacted at flush; default unbounded)")
    ap.add_argument("--backend", default="numpy",
                    choices=["numpy", "jax", "none"],
                    help="evaluation-engine array backend for the sweep")
    add_sweep_args(ap)
    args = ap.parse_args()
    run(store_dir=args.store, store_cap=args.store_cap, backend=args.backend,
        sweep_kw=sweep_kwargs(args))
