"""Fig. 10: aspect-ratio exploration for flexible accelerators.

Edge (256 PEs) and cloud (2048 PEs) flexible arrays reconfigured to every
aspect ratio; per DNN workload the mapper finds the best mapping under the
MAESTRO-like cost model (the paper uses MAESTRO here because it models
configurable cluster sizes). Expectation: EDP saturates once utilization
is maximized; balanced ratios win most workloads but skewed GEMMs prefer
skewed arrays -- the motivation for cluster-target mappings.
"""

from __future__ import annotations

import json
from pathlib import Path

from benchmarks.workloads import CLOUD_ASPECTS, EDGE_ASPECTS, dnn_layers
from repro.core.architecture import cloud_accelerator, edge_accelerator
from repro.core.optimizer import union_opt

OUT = Path("experiments/benchmarks")


def run() -> dict:
    layers = dnn_layers()
    result = {"figure": "fig10", "edge": {}, "cloud": {}}
    for tag, mk, aspects in (
        ("edge", edge_accelerator, EDGE_ASPECTS),
        ("cloud", cloud_accelerator, CLOUD_ASPECTS),
    ):
        for wname, problem in layers.items():
            row = {}
            for aspect in aspects:
                arch = mk(aspect=aspect)
                sol = union_opt(problem, arch, mapper="heuristic",
                                cost_model="maestro", metric="edp")
                row["x".join(map(str, aspect))] = {
                    "edp": sol.cost.edp, "util": sol.cost.utilization,
                    "search": sol.search.stats_dict(),
                }
            result[tag][wname] = row
            best = min(row, key=lambda k: row[k]["edp"])
            print(f"[fig10] {tag:5s} {wname:10s} best aspect {best:8s} "
                  f"(util {row[best]['util']:.0%})")
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "fig10.json").write_text(json.dumps(result, indent=1))
    return result


if __name__ == "__main__":
    run()
